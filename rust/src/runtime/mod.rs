//! PJRT runtime: load and execute the AOT-compiled assign-step artifacts.
//!
//! `python -m compile.aot` (run once by `make artifacts`, never at request
//! time) lowers the L2 JAX graph — which calls the L1 Pallas kernel — to
//! HLO **text** for a lattice of `(chunk, d, k)` shapes and writes a
//! `manifest.tsv`. This module loads the manifest, compiles artifacts
//! on the PJRT CPU client on first use, and exposes a padded, chunked
//! [`AssignExecutor::assign`] with the exact padding protocol the kernel
//! was built for (see `python/compile/model.py`):
//!
//! * rows are zero-padded to the chunk size with weight 0 (their outputs
//!   are discarded and they contribute nothing to the partial sums);
//! * columns (d) are zero-padded — distance preserving;
//! * centers (k) are padded with a large finite sentinel so a pad center
//!   can never be the nearest or second-nearest of a real point.
//!
//! HLO text (not a serialized `HloModuleProto`) is the interchange format:
//! jax >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids.

pub mod executor;
pub mod lloyd_xla;

pub use executor::{AssignExecutor, AssignOutput, Manifest};
pub use lloyd_xla::run as lloyd_xla;

/// Sentinel coordinate for padded centers. Must match
/// `compile.kernels.assign.PAD_CENTER_VALUE`: large enough to never win,
/// small enough that the f32 squared-distance expansion stays finite.
pub const PAD_CENTER_VALUE: f32 = 1.0e15;

/// Default artifacts directory, overridable with `COVERMEANS_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("COVERMEANS_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}
