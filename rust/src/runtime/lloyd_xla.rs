//! Standard k-means with the assign step on the XLA/PJRT path — the
//! end-to-end proof that all three layers compose (L3 loop, L2 graph, L1
//! Pallas kernel), and the backend of the `--backend xla` CLI option.
//!
//! Semantics match [`crate::kmeans::lloyd`] up to f32 rounding on the
//! compiled path (the artifacts are f32 like real accelerator kernels; the
//! native path is f64). Distance computations are counted semantically:
//! each chunk execution accounts `rows * k` evaluations, so the paper's
//! relative-distance metrics are backend independent.

use anyhow::Result;

use crate::data::Matrix;
use crate::kmeans::KMeansParams;
use crate::metrics::{DistCounter, IterationLog, RunResult, Stopwatch};
use crate::runtime::AssignExecutor;

pub fn run(
    data: &Matrix,
    init: &Matrix,
    params: &KMeansParams,
    exec: &mut AssignExecutor,
) -> Result<RunResult> {
    let n = data.rows();
    let d = data.cols();
    let k = init.rows();
    let sw = Stopwatch::start();
    let mut dist = DistCounter::new();

    let mut centers = init.clone();
    let mut labels = vec![u32::MAX; n];
    let mut movement: Vec<f64> = Vec::with_capacity(k);
    let mut log = IterationLog::new();
    let mut converged = false;
    let mut iterations = 0;

    for iter in 1..=params.max_iter {
        iterations = iter;
        let out = exec.assign(data, &centers)?;
        dist.add_bulk((n * k) as u64);

        let mut changed = 0usize;
        for i in 0..n {
            if labels[i] != out.labels[i] {
                labels[i] = out.labels[i];
                changed += 1;
            }
        }

        // Centroid update from the kernel's partial sums (empty clusters
        // keep their center, matching the native path).
        movement.clear();
        let mut new_row = vec![0.0; d];
        for c in 0..k {
            if out.counts[c] > 0.0 {
                let inv = 1.0 / out.counts[c];
                for j in 0..d {
                    new_row[j] = out.sums.get(c, j) * inv;
                }
                let mv = dist.d(centers.row(c), &new_row);
                centers.row_mut(c).copy_from_slice(&new_row);
                movement.push(mv);
            } else {
                movement.push(0.0);
            }
        }

        log.push(iter, dist.count(), sw.elapsed(), changed);
        if changed == 0 {
            converged = true;
            break;
        }
    }

    Ok(RunResult {
        labels,
        centers,
        iterations,
        distances: dist.count(),
        build_dist: 0,
        time: sw.elapsed(),
        build_time: std::time::Duration::ZERO,
        log,
        converged,
    })
}
