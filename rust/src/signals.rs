//! Process-global signal flags shared by the long-running CLI verbs.
//!
//! `covermeans serve` polls these from its accept loop (SIGHUP → reload,
//! SIGINT/SIGTERM → graceful drain); `covermeans run` polls
//! [`take_shutdown`] at iteration boundaries to checkpoint-then-exit
//! instead of dying mid-fit. Raw `signal(2)` FFI keeps the crate
//! dependency-free; handlers only store to atomics
//! (async-signal-safe). Handlers are process-global, so in-process tests
//! must never call [`install`] — only the CLI does.

#[cfg(unix)]
mod imp {
    use std::sync::atomic::{AtomicBool, Ordering};

    static SHUTDOWN: AtomicBool = AtomicBool::new(false);
    static RELOAD: AtomicBool = AtomicBool::new(false);

    const SIGHUP: i32 = 1;
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> isize;
    }

    extern "C" fn on_shutdown(_sig: i32) {
        SHUTDOWN.store(true, Ordering::SeqCst);
    }

    extern "C" fn on_reload(_sig: i32) {
        RELOAD.store(true, Ordering::SeqCst);
    }

    /// Register the handlers (idempotent; CLI only).
    pub fn install() {
        unsafe {
            signal(SIGHUP, on_reload);
            signal(SIGINT, on_shutdown);
            signal(SIGTERM, on_shutdown);
        }
    }

    /// Consume a pending shutdown request (SIGINT/SIGTERM since the last
    /// call).
    pub fn take_shutdown() -> bool {
        SHUTDOWN.swap(false, Ordering::SeqCst)
    }

    /// Consume a pending reload request (SIGHUP since the last call).
    pub fn take_reload() -> bool {
        RELOAD.swap(false, Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod imp {
    /// No-op off unix: the serve `RELOAD`/`SHUTDOWN` verbs still work,
    /// and `run` simply cannot be interrupted cleanly.
    pub fn install() {}

    pub fn take_shutdown() -> bool {
        false
    }

    pub fn take_reload() -> bool {
        false
    }
}

pub use imp::{install, take_reload, take_shutdown};
