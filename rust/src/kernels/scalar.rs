//! The portable reference kernels — the ground truth every SIMD path
//! must match bit for bit (see the [module docs](super) for the proof
//! sketch). The f64 kernel is the crate's historical 4-accumulator loop,
//! moved here verbatim from `data/matrix.rs`; the f32 kernel uses eight
//! accumulators with a fixed reduction tree chosen to coincide with the
//! natural 8×f32 AVX horizontal sum.

use crate::data::Matrix;

/// Squared Euclidean distance between two equal-length rows.
///
/// Four independent accumulators over quads, separately rounded multiply
/// and add, fixed `(s0+s2)+(s1+s3)` reduction, scalar tail — the lane
/// structure the SIMD kernels replicate exactly.
#[inline]
pub fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    let ca = a.chunks_exact(4);
    let cb = b.chunks_exact(4);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (qa, qb) in ca.zip(cb) {
        let d0 = qa[0] - qb[0];
        let d1 = qa[1] - qb[1];
        let d2 = qa[2] - qb[2];
        let d3 = qa[3] - qb[3];
        s0 += d0 * d0;
        s1 += d1 * d1;
        s2 += d2 * d2;
        s3 += d3 * d3;
    }
    let mut acc = (s0 + s2) + (s1 + s3);
    for (x, y) in ra.iter().zip(rb) {
        let d = x - y;
        acc += d * d;
    }
    acc
}

/// Squared Euclidean distance in f32.
///
/// Eight accumulators over octets; the reduction folds halves first
/// (`t_i = s_i + s_{i+4}`) and then the same `(t0+t2)+(t1+t3)` tree as
/// the f64 kernel — exactly the order of an 8×f32 AVX register's
/// 128-bit-half + `movehl` horizontal sum, so SIMD ≡ scalar holds in
/// f32 too (and with it, the f32 serving path's fallback counts).
#[inline]
pub fn sqdist_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = [0.0f32; 8];
    let ca = a.chunks_exact(8);
    let cb = b.chunks_exact(8);
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (qa, qb) in ca.zip(cb) {
        for lane in 0..8 {
            let d = qa[lane] - qb[lane];
            s[lane] += d * d;
        }
    }
    let t0 = s[0] + s[4];
    let t1 = s[1] + s[5];
    let t2 = s[2] + s[6];
    let t3 = s[3] + s[7];
    let mut acc = (t0 + t2) + (t1 + t3);
    for (x, y) in ra.iter().zip(rb) {
        let d = x - y;
        acc += d * d;
    }
    acc
}

/// One point against every center row: nearest and second-nearest by
/// Euclidean distance, ties to the lowest index. Returns
/// `(c1, d1, c2, d2)`; with a single center `d2` is infinite. Exactly the
/// comparison sequence of the historical per-row loop in
/// `kmeans::bounds::nearest_two`.
pub fn argmin2(point: &[f64], centers: &Matrix) -> (u32, f64, u32, f64) {
    let mut c1 = 0u32;
    let mut d1 = f64::INFINITY;
    let mut c2 = 0u32;
    let mut d2 = f64::INFINITY;
    for i in 0..centers.rows() {
        let dd = sqdist(point, centers.row(i)).sqrt();
        if dd < d1 {
            c2 = c1;
            d2 = d1;
            c1 = i as u32;
            d1 = dd;
        } else if dd < d2 {
            c2 = i as u32;
            d2 = dd;
        }
    }
    (c1, d1, c2, d2)
}

/// f32 variant of [`argmin2`] over a flat row-major `k × d` center
/// buffer. Returns **squared** distances (the serving path compares and
/// then takes square roots in f64; squaring is monotone, so the argmin
/// and tie order are unchanged).
pub fn argmin2_f32(point: &[f32], centers: &[f32], d: usize) -> (u32, f32, u32, f32) {
    let k = if d == 0 { 0 } else { centers.len() / d };
    let mut c1 = 0u32;
    let mut d1 = f32::INFINITY;
    let mut c2 = 0u32;
    let mut d2 = f32::INFINITY;
    for i in 0..k {
        let dd = sqdist_f32(point, &centers[i * d..(i + 1) * d]);
        if dd < d1 {
            c2 = c1;
            d2 = d1;
            c1 = i as u32;
            d1 = dd;
        } else if dd < d2 {
            c2 = i as u32;
            d2 = dd;
        }
    }
    (c1, d1, c2, d2)
}
