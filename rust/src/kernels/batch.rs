//! Batched scan entry points: one-point-vs-many-centers argmin and the
//! cache-blocked inter-center pass. These change *loop structure* only —
//! the per-pair arithmetic is the dispatched [`super::sqdist`], so
//! everything here inherits the module's bit-identity guarantee.

use super::scalar;
use crate::data::Matrix;

/// Nearest and second-nearest center for `point`, ties to the lowest
/// index: `(c1, d1, c2, d2)` with Euclidean (not squared) distances and
/// `d2 = ∞` when there is a single center.
///
/// Dispatch is hoisted out of the scan: the SIMD variants run the whole
/// k-row loop inside one `target_feature` region, amortizing loads of
/// `point` across center rows instead of paying a dispatch branch per
/// distance. Exactly the comparison sequence of the historical per-row
/// loop, so results are byte-identical under every dispatch.
#[inline]
pub fn argmin2(point: &[f64], centers: &Matrix) -> (u32, f64, u32, f64) {
    #[cfg(target_arch = "x86_64")]
    if super::active() == super::Dispatch::Avx {
        // Safety: Avx is only selected after runtime feature detection.
        return unsafe { super::x86::argmin2_avx(point, centers) };
    }
    #[cfg(target_arch = "aarch64")]
    if super::active() == super::Dispatch::Neon {
        return super::neon::argmin2_neon(point, centers);
    }
    scalar::argmin2(point, centers)
}

/// f32 [`argmin2`] over a flat row-major `k × d` center buffer,
/// returning **squared** distances (monotone in the true distance, so
/// argmin and tie order match; the serving path converts to f64 and
/// takes roots only for its error-bound test).
#[inline]
pub fn argmin2_f32(point: &[f32], centers: &[f32], d: usize) -> (u32, f32, u32, f32) {
    #[cfg(target_arch = "x86_64")]
    if super::active() == super::Dispatch::Avx {
        // Safety: Avx is only selected after runtime feature detection.
        return unsafe { super::x86::argmin2_f32_avx(point, centers, d) };
    }
    #[cfg(target_arch = "aarch64")]
    if super::active() == super::Dispatch::Neon {
        return super::neon::argmin2_f32_neon(point, centers, d);
    }
    scalar::argmin2_f32(point, centers, d)
}

/// Row-block size of [`pairwise_upper`]: 8 rows of the i-block stay hot
/// while a j-tile streams past them.
const TILE_I: usize = 8;
/// Column-tile size of [`pairwise_upper`].
const TILE_J: usize = 32;

/// Cache-blocked upper-triangle pairwise pass over the center rows:
/// `emit(i, j, d(c_i, c_j))` exactly once per unordered pair `i < j`.
///
/// The O(k²d) inter-center pass used to stream the full matrix once per
/// row; tiling re-uses an 8-row block against 32-row tiles so each block
/// of operands is loaded from cache, not memory. Emission *order* differs
/// from the row-wise loop, but each pair's distance is an independent
/// [`super::sqdist`] and the consumer (`InterCenter`'s per-row minimum)
/// is order-free, so results stay byte-identical.
pub fn pairwise_upper(centers: &Matrix, mut emit: impl FnMut(usize, usize, f64)) {
    let k = centers.rows();
    let mut ib = 0;
    while ib < k {
        let ie = (ib + TILE_I).min(k);
        let mut jb = ib + 1;
        while jb < k {
            let je = (jb + TILE_J).min(k);
            for j in jb..je {
                let cj = centers.row(j);
                for i in ib..ie.min(j) {
                    emit(i, j, super::sqdist(centers.row(i), cj).sqrt());
                }
            }
            jb = je;
        }
        ib = ie;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_centers(k: usize, d: usize) -> Matrix {
        let mut m = Matrix::zeros(k, d);
        for i in 0..k {
            for j in 0..d {
                m.row_mut(i)[j] = ((i * 31 + j * 7) % 17) as f64 * 0.25 - 1.0;
            }
        }
        m
    }

    #[test]
    fn argmin2_matches_scalar_reference() {
        let centers = toy_centers(37, 13);
        let q: Vec<f64> = (0..13).map(|i| (i as f64) * 0.1 - 0.3).collect();
        let got = argmin2(&q, &centers);
        let want = scalar::argmin2(&q, &centers);
        assert_eq!(got.0, want.0);
        assert_eq!(got.1.to_bits(), want.1.to_bits());
        assert_eq!(got.2, want.2);
        assert_eq!(got.3.to_bits(), want.3.to_bits());
    }

    #[test]
    fn argmin2_single_center_second_is_infinite() {
        let centers = toy_centers(1, 5);
        let (c1, d1, _, d2) = argmin2(&[0.0; 5], &centers);
        assert_eq!(c1, 0);
        assert!(d1.is_finite());
        assert_eq!(d2, f64::INFINITY);
    }

    #[test]
    fn pairwise_upper_emits_each_pair_once() {
        for k in [0usize, 1, 2, 7, TILE_I, TILE_I + 1, 50] {
            let centers = toy_centers(k.max(1), 6);
            let centers = if k == 0 { Matrix::zeros(0, 6) } else { centers };
            let mut seen = std::collections::HashSet::new();
            let mut count = 0usize;
            pairwise_upper(&centers, |i, j, dd| {
                assert!(i < j, "k={k}");
                assert!(j < k, "k={k}");
                assert!(seen.insert((i, j)), "duplicate pair ({i},{j}) k={k}");
                let want = super::super::sqdist(centers.row(i), centers.row(j)).sqrt();
                assert_eq!(dd.to_bits(), want.to_bits());
                count += 1;
            });
            assert_eq!(count, k * (k.max(1) - 1) / 2, "k={k}");
        }
    }
}
