//! NEON kernels for aarch64. NEON (ASIMD) is architecturally guaranteed
//! on aarch64, so these are safe functions selected unconditionally by
//! [`super::active`] (unless the scalar escape hatch is engaged).
//!
//! Bit-identity with the scalar reference follows the same argument as
//! the AVX kernels (see the [module docs](super)): the f64 kernel keeps
//! the four scalar accumulators as two 2-lane vectors `[s0,s1]` and
//! `[s2,s3]`, adds them into `[s0+s2, s1+s3]`, and finishes lane0 +
//! lane1 — exactly `(s0+s2)+(s1+s3)`; multiplies and adds round
//! separately (`vmulq` + `vaddq`, never `vfmaq`).

#![cfg(target_arch = "aarch64")]

use std::arch::aarch64::*;

use crate::data::Matrix;

/// NEON twin of [`super::scalar::sqdist`].
#[inline]
pub fn sqdist_neon(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len().min(b.len());
    let quads = n / 4;
    unsafe {
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc01 = vdupq_n_f64(0.0); // lanes [s0, s1]
        let mut acc23 = vdupq_n_f64(0.0); // lanes [s2, s3]
        for q in 0..quads {
            let a0 = vld1q_f64(pa.add(q * 4));
            let a1 = vld1q_f64(pa.add(q * 4 + 2));
            let b0 = vld1q_f64(pb.add(q * 4));
            let b1 = vld1q_f64(pb.add(q * 4 + 2));
            let d0 = vsubq_f64(a0, b0);
            let d1 = vsubq_f64(a1, b1);
            // vmul + vadd, never vfma: two roundings like the scalar loop.
            acc01 = vaddq_f64(acc01, vmulq_f64(d0, d0));
            acc23 = vaddq_f64(acc23, vmulq_f64(d1, d1));
        }
        let t = vaddq_f64(acc01, acc23); // [s0+s2, s1+s3]
        let mut out = vgetq_lane_f64::<0>(t) + vgetq_lane_f64::<1>(t);
        for i in quads * 4..n {
            let d = *pa.add(i) - *pb.add(i);
            out += d * d;
        }
        out
    }
}

/// NEON twin of [`super::scalar::sqdist_f32`].
#[inline]
pub fn sqdist_f32_neon(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len().min(b.len());
    let octs = n / 8;
    unsafe {
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc0 = vdupq_n_f32(0.0); // lanes [s0..s3]
        let mut acc1 = vdupq_n_f32(0.0); // lanes [s4..s7]
        for q in 0..octs {
            let a0 = vld1q_f32(pa.add(q * 8));
            let a1 = vld1q_f32(pa.add(q * 8 + 4));
            let b0 = vld1q_f32(pb.add(q * 8));
            let b1 = vld1q_f32(pb.add(q * 8 + 4));
            let d0 = vsubq_f32(a0, b0);
            let d1 = vsubq_f32(a1, b1);
            acc0 = vaddq_f32(acc0, vmulq_f32(d0, d0));
            acc1 = vaddq_f32(acc1, vmulq_f32(d1, d1));
        }
        let t = vaddq_f32(acc0, acc1); // [t0..t3] = [s0+s4, ...]
        let mut out = (vgetq_lane_f32::<0>(t) + vgetq_lane_f32::<2>(t))
            + (vgetq_lane_f32::<1>(t) + vgetq_lane_f32::<3>(t));
        for i in octs * 8..n {
            let d = *pa.add(i) - *pb.add(i);
            out += d * d;
        }
        out
    }
}

/// NEON twin of [`super::scalar::argmin2`] (scan hoisted so the per-row
/// kernel inlines).
pub fn argmin2_neon(point: &[f64], centers: &Matrix) -> (u32, f64, u32, f64) {
    let mut c1 = 0u32;
    let mut d1 = f64::INFINITY;
    let mut c2 = 0u32;
    let mut d2 = f64::INFINITY;
    for i in 0..centers.rows() {
        let dd = sqdist_neon(point, centers.row(i)).sqrt();
        if dd < d1 {
            c2 = c1;
            d2 = d1;
            c1 = i as u32;
            d1 = dd;
        } else if dd < d2 {
            c2 = i as u32;
            d2 = dd;
        }
    }
    (c1, d1, c2, d2)
}

/// NEON twin of [`super::scalar::argmin2_f32`] (squared distances, flat
/// `k × d` buffer).
pub fn argmin2_f32_neon(
    point: &[f32],
    centers: &[f32],
    d: usize,
) -> (u32, f32, u32, f32) {
    let k = if d == 0 { 0 } else { centers.len() / d };
    let mut c1 = 0u32;
    let mut d1 = f32::INFINITY;
    let mut c2 = 0u32;
    let mut d2 = f32::INFINITY;
    for i in 0..k {
        let dd = sqdist_f32_neon(point, &centers[i * d..(i + 1) * d]);
        if dd < d1 {
            c2 = c1;
            d2 = d1;
            c1 = i as u32;
            d1 = dd;
        } else if dd < d2 {
            c2 = i as u32;
            d2 = dd;
        }
    }
    (c1, d1, c2, d2)
}
