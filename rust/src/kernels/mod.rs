//! The one home of all Euclidean distance math: runtime-dispatched SIMD
//! kernels with a bit-identical scalar fallback, batched argmin scans,
//! and the reduced-precision (f32) serving kernels.
//!
//! Every point–point and point–center distance the crate computes funnels
//! through this module — [`crate::data::matrix::sqdist`] and
//! [`crate::metrics::DistCounter`] are thin shims over [`sqdist`]/[`dist`]
//! here, and the survivors loops, leaf scans, and predict paths call the
//! batched entry points ([`argmin2`], [`pairwise_upper`]) directly.
//!
//! # Dispatch
//!
//! The kernel implementation is selected **once per process** and cached:
//!
//! 1. If the `COVERMEANS_FORCE_SCALAR` environment variable is set to a
//!    non-empty value other than `0`, the scalar kernels run everywhere
//!    (the escape hatch for bug triage and A/B benchmarking).
//! 2. On `x86_64`, runtime detection (`is_x86_feature_detected!("avx2")`)
//!    selects the AVX kernels in [`x86`].
//! 3. On `aarch64`, NEON is architecturally guaranteed, so the [`neon`]
//!    kernels are always selected.
//! 4. Anything else falls back to the [`scalar`] kernels — the exact
//!    4-accumulator loop the crate has always used.
//!
//! The selected name is reported by [`active_name`] and surfaces in the
//! CLI startup line, the serving daemon's `STATS` counters, and the CSV
//! provenance headers, so every artifact is attributable to a code path.
//!
//! # Bit-identity (the reason this is safe)
//!
//! The repo's determinism contract (`threads=N ≡ threads=1` byte for
//! byte, GUIDE §3) extends across dispatch: **SIMD ≡ scalar, bit for
//! bit**. That is engineered, not hoped for. The scalar f64 kernel keeps
//! four independent accumulators over `chunks_exact(4)` —
//!
//! ```text
//! s0 += d0*d0;  s1 += d1*d1;  s2 += d2*d2;  s3 += d3*d3;   // per quad
//! acc = (s0 + s2) + (s1 + s3);                              // fixed tree
//! acc += d*d for each remainder element                     // scalar tail
//! ```
//!
//! — which maps 1:1 onto a 4×f64 vector accumulator: lane *i* of the AVX
//! accumulator receives exactly the operands of `s_i`, in the same order,
//! with separately rounded multiply and add (**no FMA** — a fused
//! multiply-add rounds once where the scalar kernel rounds twice, which
//! is precisely the kind of silent divergence this module exists to
//! forbid). The horizontal reduction extracts the 128-bit halves and adds
//! them in the same fixed `(s0+s2)+(s1+s3)` tree, and the remainder lanes
//! run the identical scalar tail. IEEE-754 ops are deterministic given
//! operands, operation, and rounding order — all three are equal by
//! construction, so every intermediate, and the result, is bit-identical.
//! The same argument covers NEON (two 2-lane accumulators `[s0,s1]` /
//! `[s2,s3]`) and the f32 kernel (eight accumulators folded
//! `(t0+t2)+(t1+t3)` with `t_i = s_i + s_{i+4}`, matching the natural
//! 8×f32 AVX reduction). `rust/tests/kernels.rs` property-tests the
//! equality across dimensions 0..=67, subnormals, signed zeros, and
//! large-magnitude inputs; CI runs the suite under both dispatches.
//!
//! The batched scans change loop structure only, never arithmetic:
//! [`argmin2`] performs the same per-row `sqrt(sqdist)` comparisons as
//! the historical per-row loop (lowest index wins ties), and
//! [`pairwise_upper`] tiles the O(k²d) inter-center pass for cache reuse
//! while emitting each unordered pair exactly once — the consumer's
//! row-min is order-free, so the tiling is invisible in the output.

use std::sync::OnceLock;

pub mod batch;
pub mod scalar;

#[cfg(target_arch = "aarch64")]
pub mod neon;
#[cfg(target_arch = "x86_64")]
pub mod x86;

pub use batch::{argmin2, argmin2_f32, pairwise_upper};

/// Which kernel implementation the process dispatches to (selected once,
/// see the [module docs](self)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dispatch {
    /// The portable 4-accumulator scalar loop (always available).
    Scalar,
    /// 256-bit AVX vectors on x86_64 (runtime-detected; no FMA — see the
    /// bit-identity notes in the [module docs](self)).
    Avx,
    /// 128-bit NEON vectors on aarch64 (architecturally guaranteed).
    Neon,
}

impl Dispatch {
    /// Lower-case name used in log lines, `STATS`, and CSV provenance.
    pub fn name(self) -> &'static str {
        match self {
            Dispatch::Scalar => "scalar",
            Dispatch::Avx => "avx",
            Dispatch::Neon => "neon",
        }
    }
}

/// Is the `COVERMEANS_FORCE_SCALAR` escape hatch engaged? (Set to any
/// non-empty value other than `0`.)
pub fn force_scalar() -> bool {
    match std::env::var_os("COVERMEANS_FORCE_SCALAR") {
        Some(v) => !v.is_empty() && v != "0",
        None => false,
    }
}

fn detect() -> Dispatch {
    if force_scalar() {
        return Dispatch::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return Dispatch::Avx;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return Dispatch::Neon;
    }
    #[allow(unreachable_code)]
    Dispatch::Scalar
}

static DISPATCH: OnceLock<Dispatch> = OnceLock::new();

/// The dispatch selected for this process (detection runs on first call
/// and is cached; the env escape hatch is read at that point).
#[inline]
pub fn active() -> Dispatch {
    *DISPATCH.get_or_init(detect)
}

/// [`active`]'s name — the string logged at startup and recorded in
/// `STATS` / CSV provenance.
pub fn active_name() -> &'static str {
    active().name()
}

/// Squared Euclidean distance, dispatched. Bit-identical to
/// [`scalar::sqdist`] under every dispatch.
#[inline]
pub fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    #[cfg(target_arch = "x86_64")]
    if active() == Dispatch::Avx {
        // Safety: `Avx` is only ever selected after runtime detection
        // confirmed the feature on this CPU.
        return unsafe { x86::sqdist_avx(a, b) };
    }
    #[cfg(target_arch = "aarch64")]
    if active() == Dispatch::Neon {
        return neon::sqdist_neon(a, b);
    }
    scalar::sqdist(a, b)
}

/// Euclidean distance, dispatched (`sqrt` of [`sqdist`]).
#[inline]
pub fn dist(a: &[f64], b: &[f64]) -> f64 {
    sqdist(a, b).sqrt()
}

/// Squared Euclidean distance in f32, dispatched. Bit-identical to
/// [`scalar::sqdist_f32`] under every dispatch, so the f32 serving
/// path's fallback decisions are dispatch-invariant too.
#[inline]
pub fn sqdist_f32(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    if active() == Dispatch::Avx {
        // Safety: as in `sqdist` — Avx implies detection succeeded.
        return unsafe { x86::sqdist_f32_avx(a, b) };
    }
    #[cfg(target_arch = "aarch64")]
    if active() == Dispatch::Neon {
        return neon::sqdist_f32_neon(a, b);
    }
    scalar::sqdist_f32(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_is_cached_and_named() {
        let a = active();
        assert_eq!(a, active(), "detection must be stable");
        assert!(["scalar", "avx", "neon"].contains(&active_name()));
        if force_scalar() {
            assert_eq!(a, Dispatch::Scalar, "escape hatch must win");
        }
    }

    #[test]
    fn dispatched_matches_scalar_bits() {
        // The heavyweight property suite lives in rust/tests/kernels.rs;
        // this is the smoke version that runs with every unit test pass.
        for d in [0usize, 1, 3, 4, 7, 32, 67] {
            let a: Vec<f64> =
                (0..d).map(|i| (i as f64).sin() * 1e3 + 0.125).collect();
            let b: Vec<f64> = (0..d).map(|i| (i as f64).cos() * 1e-3).collect();
            assert_eq!(
                sqdist(&a, &b).to_bits(),
                scalar::sqdist(&a, &b).to_bits(),
                "d={d}"
            );
            let af: Vec<f32> = a.iter().map(|&v| v as f32).collect();
            let bf: Vec<f32> = b.iter().map(|&v| v as f32).collect();
            assert_eq!(
                sqdist_f32(&af, &bf).to_bits(),
                scalar::sqdist_f32(&af, &bf).to_bits(),
                "d={d}"
            );
        }
    }
}
