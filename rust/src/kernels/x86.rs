//! AVX kernels for x86_64, selected at runtime by
//! [`super::active`] when the CPU reports `avx2`.
//!
//! Deliberately **no FMA**: `_mm256_fmadd_pd` rounds the product and sum
//! once, the scalar reference rounds them separately, and bit-identity to
//! the scalar kernel is the contract (see the [module docs](super)).
//! Every function here is `#[target_feature(enable = "avx")]` (the
//! 256-bit float ops used are AVX; detecting `avx2` implies it) and
//! therefore `unsafe` to call — callers must have confirmed detection,
//! which [`super::active`] guarantees.

#![cfg(target_arch = "x86_64")]

use std::arch::x86_64::*;

use crate::data::Matrix;

/// AVX twin of [`super::scalar::sqdist`]: lane *i* of the accumulator is
/// exactly the scalar kernel's `s_i`.
///
/// # Safety
/// The CPU must support AVX (runtime-detected by [`super::active`]).
#[target_feature(enable = "avx")]
pub unsafe fn sqdist_avx(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len().min(b.len());
    let quads = n / 4;
    let pa = a.as_ptr();
    let pb = b.as_ptr();
    let mut acc = _mm256_setzero_pd();
    for q in 0..quads {
        let va = _mm256_loadu_pd(pa.add(q * 4));
        let vb = _mm256_loadu_pd(pb.add(q * 4));
        let d = _mm256_sub_pd(va, vb);
        // Separate multiply and add (not fmadd): two roundings, exactly
        // like `s_i += d_i * d_i` in the scalar loop.
        acc = _mm256_add_pd(acc, _mm256_mul_pd(d, d));
    }
    // Fixed (s0+s2)+(s1+s3) reduction: low half [s0,s1] + high half
    // [s2,s3] gives [s0+s2, s1+s3]; then lane0 + lane1.
    let lo = _mm256_castpd256_pd128(acc);
    let hi = _mm256_extractf128_pd::<1>(acc);
    let t = _mm_add_pd(lo, hi);
    let mut out = _mm_cvtsd_f64(t) + _mm_cvtsd_f64(_mm_unpackhi_pd(t, t));
    for i in quads * 4..n {
        let d = *pa.add(i) - *pb.add(i);
        out += d * d;
    }
    out
}

/// AVX twin of [`super::scalar::sqdist_f32`]: eight lanes, halves folded
/// first, then the `(t0+t2)+(t1+t3)` tree.
///
/// # Safety
/// The CPU must support AVX (runtime-detected by [`super::active`]).
#[target_feature(enable = "avx")]
pub unsafe fn sqdist_f32_avx(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len().min(b.len());
    let octs = n / 8;
    let pa = a.as_ptr();
    let pb = b.as_ptr();
    let mut acc = _mm256_setzero_ps();
    for q in 0..octs {
        let va = _mm256_loadu_ps(pa.add(q * 8));
        let vb = _mm256_loadu_ps(pb.add(q * 8));
        let d = _mm256_sub_ps(va, vb);
        acc = _mm256_add_ps(acc, _mm256_mul_ps(d, d));
    }
    // [s0..s3] + [s4..s7] = [t0..t3]; then (t0+t2)+(t1+t3).
    let lo = _mm256_castps256_ps128(acc);
    let hi = _mm256_extractf128_ps::<1>(acc);
    let t = _mm_add_ps(lo, hi);
    let u = _mm_add_ps(t, _mm_movehl_ps(t, t));
    let mut out = _mm_cvtss_f32(u) + _mm_cvtss_f32(_mm_shuffle_ps::<0x55>(u, u));
    for i in octs * 8..n {
        let d = *pa.add(i) - *pb.add(i);
        out += d * d;
    }
    out
}

/// AVX-hoisted twin of [`super::scalar::argmin2`]: the whole scan runs
/// inside one `target_feature` region so the per-row kernel inlines
/// instead of paying a dispatch branch per center.
///
/// # Safety
/// The CPU must support AVX (runtime-detected by [`super::active`]).
#[target_feature(enable = "avx")]
pub unsafe fn argmin2_avx(point: &[f64], centers: &Matrix) -> (u32, f64, u32, f64) {
    let mut c1 = 0u32;
    let mut d1 = f64::INFINITY;
    let mut c2 = 0u32;
    let mut d2 = f64::INFINITY;
    for i in 0..centers.rows() {
        let dd = sqdist_avx(point, centers.row(i)).sqrt();
        if dd < d1 {
            c2 = c1;
            d2 = d1;
            c1 = i as u32;
            d1 = dd;
        } else if dd < d2 {
            c2 = i as u32;
            d2 = dd;
        }
    }
    (c1, d1, c2, d2)
}

/// AVX-hoisted twin of [`super::scalar::argmin2_f32`] (squared
/// distances, flat `k × d` buffer).
///
/// # Safety
/// The CPU must support AVX (runtime-detected by [`super::active`]).
#[target_feature(enable = "avx")]
pub unsafe fn argmin2_f32_avx(
    point: &[f32],
    centers: &[f32],
    d: usize,
) -> (u32, f32, u32, f32) {
    let k = if d == 0 { 0 } else { centers.len() / d };
    let mut c1 = 0u32;
    let mut d1 = f32::INFINITY;
    let mut c2 = 0u32;
    let mut d2 = f32::INFINITY;
    for i in 0..k {
        let dd = sqdist_f32_avx(point, &centers[i * d..(i + 1) * d]);
        if dd < d1 {
            c2 = c1;
            d2 = d1;
            c1 = i as u32;
            d1 = dd;
        } else if dd < d2 {
            c2 = i as u32;
            d2 = dd;
        }
    }
    (c1, d1, c2, d2)
}
