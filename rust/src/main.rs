//! `covermeans` — launcher CLI for the cover-tree k-means reproduction.
//!
//! Subcommands (see `covermeans help`):
//!   run       one clustering run (choice of algorithm and backend)
//!   pack      write a dataset as a `.dmat` file for out-of-core fits
//!   predict   batch nearest-center assignment from a saved model
//!   serve     resident serving daemon (batched predict over TCP)
//!   table     regenerate paper Table 2, 3 or 4
//!   fig1      regenerate the Fig. 1 per-iteration series
//!   fig2      regenerate the Fig. 2 d/k scaling series
//!   ablate    design-choice ablations (scale factor, leaf size, switch)
//!   datasets  list the dataset registry
//!   info      artifact manifest + runtime platform

use std::path::Path;

use anyhow::{bail, Context, Result};

use covermeans::config::RunConfig;
use covermeans::coordinator::{report, run_experiment, sweep, Experiment};
use covermeans::data::{io, registry, write_dmat, DataSource};
use covermeans::kmeans::{
    self, Algorithm, AlgorithmSpec, CheckpointConfig, KMeans, KMeansCheckpoint,
    KMeansModel, Workspace,
};
use covermeans::metrics::{DistCounter, RunResult};
use covermeans::parallel::Parallelism;

const HELP: &str = "\
covermeans — Accelerating k-Means Clustering with Cover Trees (reproduction)

USAGE:
  covermeans <command> [--key value ...] [--config file]

COMMANDS:
  run        single clustering run
             --dataset NAME --k K --algorithm NAME --scale S --seed N
             --backend native|xla   (xla: Standard algorithm only)
             --model_out FILE.kmm   save the fitted model for serving
             --checkpoint_path FILE.kmc  crash-safe snapshots (atomic,
             previous generation kept) [--checkpoint_every N]
             [--checkpoint_secs S]; --resume 1 continues from the newest
             valid generation, bit-identical to an uninterrupted run.
             SIGINT/SIGTERM write a snapshot then exit with code 130.
             --data_file FILE.dmat  fit a packed file instead of a
             registry dataset; --data_backend ram|mmap|chunked picks the
             residency strategy ([--data_chunk_rows N]
             [--data_resident_mb M] bound chunked-streaming memory).
             Streaming algorithms: standard, elkan, hamerly, minibatch.
             Results are byte-identical on every backend. --init
             auto|kmeans++|kmeans|| picks the seeding ([--init_rounds N]
             [--init_oversample F]; auto = ++ resident, || streamed).
  pack       write a dataset as a `.dmat` file for out-of-core runs
             --dataset NAME --out FILE.dmat [--scale S] [--data_seed N]
  predict    batch nearest-center assignment from a saved model
             --model FILE.kmm --input POINTS.csv|.fmat [--out LABELS.csv]
             [--predict_mode auto|tree|scan] [--predict_auto_k K]
             [--predict_precision f64|f32] [--fit_threads N]
  serve      resident serving daemon: load a model once, answer predict
             requests over TCP with coalescing + backpressure + hot-reload
             --model FILE.kmm [--addr HOST:PORT] [--max_batch N]
             [--batch_wait_us U] [--queue_depth N] [--fit_threads N]
             [--predict_mode auto|tree|scan] [--predict_auto_k K]
             [--predict_precision f64|f32] [--pin_workers 0|1]
             (SIGHUP or the RELOAD verb re-reads --model; SIGINT/SIGTERM
             or the SHUTDOWN verb drain and exit; see docs/GUIDE.md)
  table      --id 2|3|4 [--scale S] [--restarts N] [--warm true] — paper
             tables (--warm: id 4 with warm-started sweep restarts)
  fig1       [--scale S] [--k K] — Fig. 1 cumulative series (ALOI-64)
  fig2       --axis d|k [--scale S] [--restarts N] — Fig. 2 series
  ablate     [--scale S] [--restarts N] — design-choice ablations
  datasets   list registered datasets
  info       artifacts manifest + PJRT platform
  help       this text

CONFIG KEYS (also accepted in --config files as `key = value`; the full
table lives in docs/GUIDE.md and the config module rustdoc):
  dataset scale data_seed data_file data_backend data_chunk_rows
  data_resident_mb init init_rounds init_oversample k restarts seed
  threads fit_threads out_dir max_iter tol switch_at scale_factor
  min_node_size kd_leaf_size algorithms mb_batch mb_tol mb_seed
  model_out checkpoint_path checkpoint_every checkpoint_secs
  predict_mode predict_auto_k predict_precision pin_workers serve_addr
  max_batch batch_wait_us queue_depth

KERNELS:
  Distance arithmetic dispatches once at startup to the widest SIMD path
  the CPU offers (AVX on x86-64, NEON on aarch64) — bit-identical to the
  scalar loop; the selected kernel is logged at startup and carried in
  CSV provenance and serve STATS. Set COVERMEANS_FORCE_SCALAR=1 to pin
  the scalar path. `predict_precision f32` serves from quantized centers
  with a certified exact-fallback test: labels and distances stay
  identical to f64 serving.

THREADS:
  `threads` is the total worker budget; `fit_threads` (default 1, 0 = all
  cores) shards each fit's assignment phase and tree build. The split is
  cell_workers = threads / fit_threads. Intra-fit parallelism is
  exactness-preserving: any fit_threads value reproduces the
  single-threaded assignments and distance counts byte for byte.
";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Parse `--key value` pairs after the subcommand into the config; pairs
/// the config does not know are returned for the command to interpret.
fn parse_overrides(
    args: &[String],
    cfg: &mut RunConfig,
) -> Result<Vec<(String, String)>> {
    let mut extras = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let key = args[i]
            .strip_prefix("--")
            .with_context(|| format!("expected --key, got {:?}", args[i]))?;
        let value = args
            .get(i + 1)
            .with_context(|| format!("--{key} needs a value"))?
            .clone();
        if key == "config" {
            cfg.load_file(Path::new(&value))?;
        } else if key == "algorithm" {
            cfg.set("algorithms", &value)?;
        } else if RunConfig::KEYS.contains(&key) {
            // A known key with a bad value is its own error — it must
            // not masquerade as an unknown flag.
            cfg.set(key, &value).with_context(|| format!("--{key}"))?;
        } else {
            extras.push((key.to_string(), value));
        }
        i += 2;
    }
    Ok(extras)
}

fn extra<'a>(extras: &'a [(String, String)], key: &str) -> Option<&'a str> {
    extras.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
}

/// A typo'd flag must be a one-line error, not a silently ignored knob:
/// every command names the extras it understands and rejects the rest.
fn reject_unknown(extras: &[(String, String)], allowed: &[&str]) -> Result<()> {
    for (key, _) in extras {
        if !allowed.contains(&key.as_str()) {
            bail!(
                "unknown flag --{key}; run `covermeans help` for flags and config keys"
            );
        }
    }
    Ok(())
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print!("{HELP}");
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "run" => cmd_run(rest),
        "pack" => cmd_pack(rest),
        "predict" => cmd_predict(rest),
        "serve" => cmd_serve(rest),
        "table" => cmd_table(rest),
        "fig1" => cmd_fig1(rest),
        "fig2" => cmd_fig2(rest),
        "ablate" => cmd_ablate(rest),
        "datasets" => cmd_datasets(),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        other => bail!("unknown command {other:?}; see `covermeans help`"),
    }
}

fn cmd_run(args: &[String]) -> Result<()> {
    let mut cfg = RunConfig::default();
    let extras = parse_overrides(args, &mut cfg)?;
    reject_unknown(&extras, &["backend", "resume"])?;
    let backend = extra(&extras, "backend").unwrap_or("native");
    let resume = match extra(&extras, "resume") {
        None | Some("0") | Some("false") => false,
        Some("1") | Some("true") => true,
        Some(other) => bail!("--resume takes 1/true or 0/false, got {other:?}"),
    };
    if resume && cfg.checkpoint_path.is_empty() {
        bail!("--resume needs --checkpoint_path (the snapshot to continue from)");
    }
    let alg = cfg.algorithms[0];

    eprintln!("# config\n{}\n", cfg.dump());
    let source = if cfg.data_file.is_empty() {
        let data = registry::load(&cfg.dataset, cfg.scale, cfg.data_seed)
            .with_context(|| format!("unknown dataset {:?}", cfg.dataset))?;
        eprintln!(
            "dataset {} : n={} d={} (scale {})",
            cfg.dataset,
            data.rows(),
            data.cols(),
            cfg.scale
        );
        DataSource::from(data)
    } else {
        let source = DataSource::open(
            Path::new(&cfg.data_file),
            cfg.data_backend,
            cfg.data_chunk_rows,
            cfg.data_resident_mb,
        )
        .with_context(|| format!("open data_file {:?}", cfg.data_file))?;
        eprintln!(
            "dataset {} : n={} d={} ({} backend)",
            cfg.data_file,
            source.rows(),
            source.cols(),
            cfg.data_backend.name()
        );
        source
    };

    let params = kmeans::KMeansParams { algorithm: alg, ..cfg.params };
    let result = match backend {
        "native" => run_native(&source, &cfg, &params, alg, resume)?,
        "xla" => {
            if !cfg.checkpoint_path.is_empty() {
                bail!(
                    "checkpointing drives the native stepwise fit; drop \
                     --backend xla or checkpoint_path"
                );
            }
            let Some(data) = source.view().as_matrix() else {
                bail!(
                    "--backend xla needs resident data; use data_backend=ram \
                     or the native backend"
                );
            };
            let mut init_counter = DistCounter::new();
            let init = kmeans::init::kmeans_plus_plus(
                data,
                cfg.k.min(data.rows()),
                cfg.seed,
                &mut init_counter,
            );
            run_xla(data, &init, &params, alg)?
        }
        other => bail!("unknown backend {other:?}"),
    };

    println!("algorithm   : {}", alg.name());
    println!("backend     : {backend}");
    println!("kernel      : {}", covermeans::kernels::active_name());
    println!(
        "fit_threads : {}",
        covermeans::parallel::resolve_threads(params.threads)
    );
    println!(
        "iterations  : {} (converged: {})",
        result.iterations, result.converged
    );
    println!(
        "distances   : {} (+{} build)",
        result.distances, result.build_dist
    );
    println!(
        "time        : {:.3}s (+{:.3}s build)",
        result.time.as_secs_f64(),
        result.build_time.as_secs_f64()
    );
    println!(
        "sse         : {:.6e}",
        covermeans::metrics::sse_src(source.view(), &result.labels, &result.centers)
    );
    if !cfg.checkpoint_path.is_empty() {
        println!("checkpoint  : {} (final snapshot)", cfg.checkpoint_path);
    }
    if !cfg.model_out.is_empty() {
        let model = KMeansModel::from_run_src(source.view(), &result, alg, cfg.seed);
        let path = Path::new(&cfg.model_out);
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        model.save(path)?;
        println!("model       : saved to {} ({} bytes)", path.display(), model.to_bytes().len());
    }
    Ok(())
}

/// Materialize a registry dataset as a `.dmat` file — the packed
/// row-major f64 format the out-of-core backends (`--data_file` +
/// `--data_backend mmap|chunked`) read. Exact bits: a fit over the packed
/// file reproduces the in-RAM fit byte for byte.
fn cmd_pack(args: &[String]) -> Result<()> {
    let mut cfg = RunConfig::default();
    let extras = parse_overrides(args, &mut cfg)?;
    reject_unknown(&extras, &["out"])?;
    let out = extra(&extras, "out").context("pack needs --out <file.dmat>")?;
    let data = registry::load(&cfg.dataset, cfg.scale, cfg.data_seed)
        .with_context(|| format!("unknown dataset {:?}", cfg.dataset))?;
    let path = Path::new(out);
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    write_dmat(path, &data)?;
    let bytes = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
    println!(
        "packed      : {} (n={} d={}, scale {}) -> {} ({} bytes)",
        cfg.dataset,
        data.rows(),
        data.cols(),
        cfg.scale,
        path.display(),
        bytes
    );
    Ok(())
}

/// The native `run` path, driven stepwise so checkpoint triggers,
/// SIGINT/SIGTERM checkpoint-then-exit, and `--resume` all hang off real
/// iteration boundaries — over any data source backend (in-RAM, mmap, or
/// chunk-streamed; bit-identical results on each). MiniBatch (no exact
/// boundary) keeps the one-shot path and rejects checkpointing.
fn run_native(
    source: &DataSource,
    cfg: &RunConfig,
    params: &kmeans::KMeansParams,
    alg: Algorithm,
    resume: bool,
) -> Result<RunResult> {
    let src = source.view();
    let k = cfg.k.min(src.rows());
    let builder = |warm: Option<&KMeansCheckpoint>| {
        let mut b = KMeans::new(k)
            .algorithm(AlgorithmSpec::from_params(alg, params))
            .max_iter(params.max_iter)
            .tol(params.tol)
            .seed(cfg.seed)
            .init(cfg.init)
            .init_rounds(cfg.init_rounds)
            .init_oversample(cfg.init_oversample)
            .threads(params.threads)
            .pin_workers(params.pin_workers);
        if let Some(s) = warm {
            // Skip the seeding pass entirely: restore() overwrites the
            // centers anyway, so seed the fit straight from the snapshot.
            b = b.warm_start(s.centers.clone());
        }
        b
    };
    if alg == Algorithm::MiniBatch {
        if !cfg.checkpoint_path.is_empty() {
            bail!(
                "minibatch has no exact iteration boundary to checkpoint; \
                 drop checkpoint_path or pick an exact algorithm"
            );
        }
        return builder(None)
            .fit_source_with(source, &mut Workspace::new())
            .map_err(|e| anyhow::anyhow!("{e}"));
    }

    let checkpointing = !cfg.checkpoint_path.is_empty();
    let ckpt_path = Path::new(&cfg.checkpoint_path).to_path_buf();
    let snap = if resume {
        let (snap, generation) = KMeansCheckpoint::load_any(&ckpt_path)?;
        snap.validate_src(&builder(None).params(), src, k)?;
        eprintln!(
            "resuming    : {} at iteration {} ({} snapshot, {} distances so far)",
            snap.algorithm.name(),
            snap.iter,
            generation,
            snap.distances
        );
        Some(snap)
    } else {
        None
    };
    let mut b = builder(snap.as_ref());
    if checkpointing {
        if let Some(parent) = ckpt_path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        b = b.checkpoint(CheckpointConfig {
            path: ckpt_path,
            every: params.checkpoint_every,
            secs: params.checkpoint_secs,
        });
        covermeans::signals::install();
    }

    let mut ws = Workspace::new();
    let mut fit = b
        .fit_step_src(src, &mut ws)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    if let Some(s) = &snap {
        fit.restore(s)?;
    }
    while !fit.is_done() {
        if checkpointing && covermeans::signals::take_shutdown() {
            fit.checkpoint_now()?;
            eprintln!(
                "interrupted : snapshot written at iteration {} to {}; rerun \
                 with --resume 1 to continue",
                fit.iterations(),
                cfg.checkpoint_path
            );
            std::process::exit(130);
        }
        if fit.step().is_none() {
            break;
        }
    }
    if let Some(e) = fit.take_checkpoint_error() {
        return Err(e.context("checkpoint write failed; run stopped"));
    }
    Ok(fit.finish())
}

/// The serving half of the train-once/serve-many loop: load a `.kmm`
/// model and batch-assign a matrix of points to their nearest centers,
/// through the cover tree over the centers (or the Elkan-pruned scan —
/// `predict_mode`), sharded over `fit_threads` workers.
fn cmd_predict(args: &[String]) -> Result<()> {
    let mut cfg = RunConfig::default();
    let extras = parse_overrides(args, &mut cfg)?;
    reject_unknown(&extras, &["model", "input", "out"])?;
    let model_path = extra(&extras, "model")
        .context("predict needs --model <file.kmm> (write one with `covermeans run --model_out ...`)")?;
    let input = extra(&extras, "input")
        .context("predict needs --input <points.csv|points.fmat>")?;

    let model = KMeansModel::load(Path::new(model_path))?;
    let data = if input.ends_with(".fmat") {
        io::read_fmat(Path::new(input))?
    } else {
        io::read_csv(Path::new(input))?
    };
    if data.cols() != model.dim() {
        bail!(
            "input dimension {} does not match the model's {} (model {} with k={})",
            data.cols(),
            model.dim(),
            model.algorithm().name(),
            model.k()
        );
    }

    let par = Parallelism::new_opts(cfg.params.threads, cfg.params.pin_workers);
    let opts = kmeans::PredictOptions {
        mode: cfg.predict_mode,
        auto_k: cfg.predict_auto_k,
        threads: cfg.params.threads,
        precision: cfg.predict_precision,
    };
    let sw = std::time::Instant::now();
    let p = model.predict_opts_par(&data, &opts, &par);
    let secs = sw.elapsed().as_secs_f64();
    let naive = data.rows() as u64 * model.k() as u64;

    println!(
        "model       : {} (k={}, d={}, seed {}, {} iters, converged {})",
        model.algorithm().name(),
        model.k(),
        model.dim(),
        model.seed(),
        model.iterations(),
        model.converged()
    );
    println!("queries     : {} points from {input}", data.rows());
    println!("kernel      : {}", covermeans::kernels::active_name());
    println!(
        "mode        : {} ({}, {} threads)",
        p.mode.name(),
        p.precision.name(),
        par.threads()
    );
    if p.f32_fallbacks > 0 {
        println!(
            "fallbacks   : {} of {} queries re-answered in f64 (near-ties)",
            p.f32_fallbacks,
            data.rows()
        );
    }
    println!(
        "distances   : {} (+{} index prep) vs naive {} ({:.2}x fewer)",
        p.query_evals,
        p.prep_evals,
        naive,
        naive as f64 / (p.query_evals.max(1)) as f64
    );
    println!(
        "time        : {:.3}s ({:.0} points/s)",
        secs,
        data.rows() as f64 / secs.max(1e-12)
    );

    if let Some(out) = extra(&extras, "out") {
        let mut rows = String::with_capacity(p.labels.len() * 8);
        rows.push_str("# label,distance\n");
        for (l, d) in p.labels.iter().zip(&p.distances) {
            rows.push_str(&format!("{l},{d}\n"));
        }
        io::atomic_write(Path::new(out), rows.as_bytes())?;
        eprintln!("wrote {out}");
    }
    Ok(())
}

/// The resident half of the serving story: keep the model, its serving
/// index, and the worker pool warm in one long-lived process; coalesce
/// concurrent predict requests into single batched passes. Runs until
/// SIGINT/SIGTERM or a client's SHUTDOWN verb, draining in-flight
/// batches on the way out.
fn cmd_serve(args: &[String]) -> Result<()> {
    let mut cfg = RunConfig::default();
    let extras = parse_overrides(args, &mut cfg)?;
    reject_unknown(&extras, &["model", "addr"])?;
    let model_path = extra(&extras, "model")
        .context("serve needs --model <file.kmm> (write one with `covermeans run --model_out ...`)")?;
    let addr = extra(&extras, "addr").unwrap_or(&cfg.serve_addr).to_string();

    let serve_cfg = covermeans::serve::ServeConfig {
        model_path: Path::new(model_path).to_path_buf(),
        addr,
        max_batch: cfg.max_batch,
        batch_wait_us: cfg.batch_wait_us,
        queue_depth: cfg.queue_depth,
        mode: cfg.predict_mode,
        auto_k: cfg.predict_auto_k,
        threads: cfg.params.threads,
        precision: cfg.predict_precision,
        pin_workers: cfg.params.pin_workers,
        install_signal_handlers: true,
    };
    let mut server = covermeans::serve::Server::start(serve_cfg)?;
    let model = KMeansModel::load(Path::new(model_path))?;
    eprintln!(
        "model       : {} (k={}, d={}, {} iters, converged {})",
        model.algorithm().name(),
        model.k(),
        model.dim(),
        model.iterations(),
        model.converged()
    );
    eprintln!(
        "version     : {}",
        covermeans::serve::checksum_hex(server.model_checksum())
    );
    eprintln!(
        "batching    : max_batch {} / batch_wait_us {} / queue_depth {} / {} threads",
        cfg.max_batch,
        cfg.batch_wait_us,
        cfg.queue_depth,
        covermeans::parallel::resolve_threads(cfg.params.threads)
    );
    eprintln!(
        "kernel      : {} ({} precision{})",
        covermeans::kernels::active_name(),
        cfg.predict_precision.name(),
        if cfg.params.pin_workers { ", pinned workers" } else { "" }
    );
    // The machine-readable line e2e tooling parses to find the port.
    println!("listening {}", server.addr());
    server.wait()?;
    eprintln!("stats       : {}", server.stats_json());
    Ok(())
}

/// The `--backend xla` path: Standard algorithm with the assign step on
/// the compiled PJRT artifacts. Compiled in only with the `xla` feature.
#[cfg(feature = "xla")]
fn run_xla(
    data: &covermeans::data::Matrix,
    init: &covermeans::data::Matrix,
    params: &kmeans::KMeansParams,
    alg: Algorithm,
) -> Result<covermeans::metrics::RunResult> {
    use covermeans::runtime::{lloyd_xla, AssignExecutor};
    if alg != Algorithm::Standard {
        bail!(
            "--backend xla drives the dense assign step (Standard \
             algorithm); use native for {}",
            alg.name()
        );
    }
    let mut exec = AssignExecutor::load_default()?;
    eprintln!("PJRT platform: {}", exec.platform());
    lloyd_xla(data, init, params, &mut exec)
}

#[cfg(not(feature = "xla"))]
fn run_xla(
    _data: &covermeans::data::Matrix,
    _init: &covermeans::data::Matrix,
    _params: &kmeans::KMeansParams,
    _alg: Algorithm,
) -> Result<covermeans::metrics::RunResult> {
    bail!("this binary was built without the `xla` feature; rebuild with `--features xla`")
}

fn experiment_from_cfg(cfg: &RunConfig, mut exp: Experiment) -> Experiment {
    exp.threads = cfg.threads;
    exp.params = cfg.params;
    exp.data_seed = cfg.data_seed;
    // Interrupted sweeps resume: completed cells are recorded under
    // out_dir and skipped when the same experiment is rerun (the
    // coordinator removes the manifest once every cell is done).
    exp.manifest_path =
        Some(Path::new(&cfg.out_dir).join(format!("{}.manifest", exp.name)));
    exp
}

fn cmd_table(args: &[String]) -> Result<()> {
    let mut cfg = RunConfig::default();
    let extras = parse_overrides(args, &mut cfg)?;
    reject_unknown(&extras, &["id", "warm"])?;
    let id: u32 = extra(&extras, "id").unwrap_or("2").parse().context("--id")?;
    let warm = matches!(extra(&extras, "warm"), Some("true") | Some("1"));
    let exp = match id {
        2 | 3 => experiment_from_cfg(&cfg, sweep::tables23(cfg.scale, cfg.restarts)),
        4 if warm => experiment_from_cfg(&cfg, sweep::table4_warm(cfg.scale, cfg.restarts)),
        4 => experiment_from_cfg(&cfg, sweep::table4(cfg.scale, cfg.restarts)),
        other => bail!("no table {other}; expected 2, 3 or 4"),
    };
    eprintln!(
        "running {} cells ({} datasets x {} algorithms, {} ks, {} restarts, scale {})...",
        exp.datasets.len() * exp.algorithms.len(),
        exp.datasets.len(),
        exp.algorithms.len(),
        exp.ks.len(),
        exp.restarts,
        exp.scale
    );
    let res = run_experiment(&exp, false)?;
    let (metric, title) = match id {
        2 => (
            report::Metric::Distances,
            "Table 2: relative distance computations (k=100)",
        ),
        3 => (
            report::Metric::Time,
            "Table 3: relative run time incl. tree construction (k=100)",
        ),
        _ => (
            report::Metric::Time,
            "Table 4: relative run time, parameter sweep (amortized trees)",
        ),
    };
    println!("{}", report::render_ratio_table(&exp, &res, metric, title));
    write_csv(
        &cfg,
        &format!("table{id}.csv"),
        &report::ratio_table_csv(&exp, &res, metric),
    )
}

fn cmd_fig1(args: &[String]) -> Result<()> {
    let mut cfg = RunConfig::default();
    let extras = parse_overrides(args, &mut cfg)?;
    reject_unknown(&extras, &[])?;
    let mut exp = experiment_from_cfg(&cfg, sweep::fig1(cfg.scale));
    if cfg.k != RunConfig::default().k {
        exp.ks = vec![cfg.k]; // --k override for smaller runs
    }
    let res = run_experiment(&exp, true)?;
    let rows = report::fig1_series_csv(&exp, &res);
    println!(
        "Fig 1 (ALOI-64 analog, k={}): final cumulative ratios vs Standard",
        exp.ks[0]
    );
    let mut finals: Vec<(String, f64)> = Vec::new();
    for alg in Algorithm::ALL {
        if let Some(last) = rows.iter().filter(|r| r.starts_with(alg.name())).next_back()
        {
            let cols: Vec<&str> = last.split(',').collect();
            finals.push((
                alg.name().to_string(),
                cols[2].parse().unwrap_or(f64::NAN),
            ));
        }
    }
    print!("{}", report::ascii_chart(&finals, 40));
    write_csv(&cfg, "fig1.csv", &rows)
}

fn cmd_fig2(args: &[String]) -> Result<()> {
    let mut cfg = RunConfig::default();
    let extras = parse_overrides(args, &mut cfg)?;
    reject_unknown(&extras, &["axis"])?;
    let axis = extra(&extras, "axis").unwrap_or("d");
    let by_k = match axis {
        "d" => false,
        "k" => true,
        other => bail!("--axis must be d or k, got {other:?}"),
    };
    let exp = if by_k {
        experiment_from_cfg(&cfg, sweep::fig2b(cfg.scale, cfg.restarts))
    } else {
        experiment_from_cfg(&cfg, sweep::fig2a(cfg.scale, cfg.restarts))
    };
    let res = run_experiment(&exp, false)?;
    let rows = report::fig2_series_csv(&exp, &res, by_k);
    println!(
        "Fig 2{} series (time relative to Standard):",
        if by_k { "b" } else { "a" }
    );
    for r in &rows {
        println!("  {r}");
    }
    write_csv(
        &cfg,
        &format!("fig2{}.csv", if by_k { "b" } else { "a" }),
        &rows,
    )
}

fn cmd_ablate(args: &[String]) -> Result<()> {
    let mut cfg = RunConfig::default();
    let extras = parse_overrides(args, &mut cfg)?;
    reject_unknown(&extras, &[])?;
    let mut rows = vec!["knob,dataset,algorithm,dist_rel,time_rel".to_string()];
    for (label, mut exp) in sweep::ablations(cfg.scale, cfg.restarts.min(3)) {
        // Keep the ablated knob; adopt only the orthogonal settings
        // (including fit_threads, so the provenance header written by
        // write_csv matches what actually ran).
        exp.threads = cfg.threads;
        exp.params.threads = cfg.params.threads;
        exp.data_seed = cfg.data_seed;
        let res = run_experiment(&exp, false)?;
        for ds in &exp.datasets.clone() {
            for &alg in &exp.algorithms {
                if alg == Algorithm::Standard {
                    continue;
                }
                let dr = res
                    .ratio_vs_standard(ds, alg, |c| c.total_distances() as f64)
                    .unwrap_or(f64::NAN);
                let tr = res
                    .ratio_vs_standard(ds, alg, |c| c.total_time().as_secs_f64())
                    .unwrap_or(f64::NAN);
                println!(
                    "{label:<22} {ds:<10} {:<12} dist {dr:>7.3}  time {tr:>7.3}",
                    alg.name()
                );
                rows.push(format!("{label},{ds},{},{dr:.6},{tr:.6}", alg.name()));
            }
        }
    }
    write_csv(&cfg, "ablations.csv", &rows)
}

fn cmd_datasets() -> Result<()> {
    println!("{:<10} {:>9} {:>4}  domain", "name", "N(paper)", "d");
    for info in registry::TABLE_DATASETS.iter() {
        println!(
            "{:<10} {:>9} {:>4}  {}",
            info.name, info.n, info.d, info.domain
        );
    }
    println!("(also: mnist20/40/50, aloi<d>, blobs:<n>:<d>:<k>)");
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_info() -> Result<()> {
    println!("runtime unavailable: built without the `xla` feature");
    println!("rebuild with `cargo build --features xla` (needs xla_extension)");
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_info() -> Result<()> {
    use covermeans::runtime::AssignExecutor;
    match AssignExecutor::load_default() {
        Ok(exec) => {
            println!("PJRT platform : {}", exec.platform());
            println!(
                "artifacts     : {}",
                covermeans::runtime::artifacts_dir().display()
            );
            println!(
                "{:>6} {:>5} {:>5}  {:>10} {:>8}  file",
                "chunk", "d", "k", "vmem KiB", "mxu"
            );
            for e in &exec.manifest().entries {
                println!(
                    "{:>6} {:>5} {:>5}  {:>10.0} {:>8.3}  {}",
                    e.chunk,
                    e.d,
                    e.k,
                    e.vmem_bytes as f64 / 1024.0,
                    e.mxu_fraction,
                    e.file
                );
            }
        }
        Err(e) => {
            println!("runtime unavailable: {e:#}");
            println!("run `make artifacts` to build the HLO lattice");
        }
    }
    Ok(())
}

fn write_csv(cfg: &RunConfig, name: &str, rows: &[String]) -> Result<()> {
    let dir = Path::new(&cfg.out_dir);
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    // Provenance header: the actual thread topology (the reports used to
    // imply every run was single-threaded).
    let (cell_threads, fit_threads) =
        covermeans::coordinator::thread_split(cfg.threads, cfg.params.threads);
    let mut all = report::provenance_rows_for(cell_threads, fit_threads);
    all.extend_from_slice(rows);
    io::atomic_write(&path, (all.join("\n") + "\n").as_bytes())?;
    eprintln!("wrote {}", path.display());
    Ok(())
}
