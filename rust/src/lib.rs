//! # covermeans
//!
//! A reproduction of Lang & Schubert, *Accelerating k-Means Clustering with
//! Cover Trees* (DOI 10.1007/978-3-031-46994-7_13), as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's algorithms: a cover tree with node
//!   aggregates, Cover-means (tree-at-once assignment with triangle-
//!   inequality pruning, §3), the Hybrid hand-off to Shallot (§3.4), and
//!   every baseline of the evaluation (Lloyd, Elkan, Hamerly, Exponion,
//!   Shallot, Kanungo's k-d-tree filter), plus the sweep coordinator and
//!   benchmark harness that regenerate the paper's tables and figures.
//! * **L2/L1 (python/, build-time only)** — the dense assign-step
//!   (distance matrix + top-2 + centroid partials) as a Pallas kernel in a
//!   JAX graph, AOT-lowered to HLO text in `artifacts/`.
//! * **runtime** — loads those artifacts through the PJRT C API (`xla`
//!   crate) so the Standard baseline and the quickstart example can run
//!   the dense step on the compiled path. Python is never on the run path.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

pub mod benchutil;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod kmeans;
pub mod metrics;
pub mod rng;
pub mod runtime;
pub mod testutil;
pub mod tree;
