//! # covermeans
//!
//! A reproduction of Lang & Schubert, *Accelerating k-Means Clustering with
//! Cover Trees* (DOI 10.1007/978-3-031-46994-7_13), as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's algorithm family behind one
//!   unified API: every exact variant (Lloyd, Elkan, Hamerly, Exponion,
//!   Shallot, Kanungo/Pelleg-Moore k-d-tree filters, Phillips, Cover-means
//!   §3, and the Hybrid hand-off to Shallot §3.4) is a
//!   [`kmeans::KMeansDriver`] — an interchangeable per-iteration strategy
//!   under the shared [`kmeans::Fit`] outer loop, which owns convergence,
//!   logging, and center recomputation. Runs are configured through the
//!   fluent [`kmeans::KMeans`] builder (typed per-algorithm knobs, warm
//!   starts, movement tolerance, per-iteration observers, stepwise
//!   `fit_step()` iteration), backed by the cover tree with node
//!   aggregates and the sweep coordinator / benchmark harness that
//!   regenerate the paper's tables and figures — including warm-started
//!   parameter sweeps that reuse centers across k.
//! * **Serving layer** — `KMeans::fit_model` captures a fit as a
//!   [`kmeans::KMeansModel`]: persistable (versioned `.kmm` binary
//!   format with checksum, plus CSV/JSON export) and able to answer
//!   batch out-of-sample `predict` queries through a cover tree built
//!   *over the centers* ([`tree::nearest`]), with an Elkan-style pruned
//!   scan for small k; queries shard over the same worker pool under the
//!   same byte-identity contract. The `covermeans run --model_out` /
//!   `covermeans predict` CLI verbs and the coordinator's
//!   `Experiment::model_dir` wire the train-once/serve-many loop
//!   end to end. `covermeans serve` keeps that model *resident*: the
//!   [`serve`] daemon answers predict requests over TCP with request
//!   coalescing into single `predict_par` passes, bounded-queue
//!   backpressure, and atomic hot-reload (swap-on-valid-parse, replies
//!   version-tagged with the model checksum).
//! * **Intra-fit parallelism** — a single fit shards every hot path
//!   (the assignment phases of all drivers including the k-d-tree
//!   filters and MiniBatch, tree construction, the inter-center matrix,
//!   and k-means++ seeding) over a **persistent worker pool** via
//!   `KMeans::new(k).threads(n)` (config key `fit_threads`; 0 = all
//!   cores). The pool is spawned once per fit — and shared across fits
//!   when a `kmeans::Workspace` is reused — so iterations pay two
//!   condvar handshakes instead of thread spawns. The [`parallel`]
//!   module's reductions are exactness-preserving: `threads = N`
//!   reproduces `threads = 1` byte for byte — same assignments, same
//!   counted `distances`, same centers — so the paper's per-algorithm
//!   distance counts are unaffected by the thread count
//!   (`rust/tests/parallel_exactness.rs`, also run in release mode in
//!   CI). The sweep coordinator splits its total thread budget between
//!   cell-level workers and intra-fit threads (`threads` /
//!   `fit_threads` config keys) and keeps one pool per cell.
//! * **L2/L1 (python/, build-time only)** — the dense assign-step
//!   (distance matrix + top-2 + centroid partials) as a Pallas kernel in a
//!   JAX graph, AOT-lowered to HLO text in `artifacts/`.
//! * **runtime** — loads those artifacts through the PJRT C API (`xla`
//!   crate) so the Standard baseline and the quickstart example can run
//!   the dense step on the compiled path. Python is never on the run path.
//!
//! The guided tour — architecture walkthrough, algorithm-selection
//! matrix, the determinism/byte-identity contract, the thread-budget
//! split, and the full config-key table — lives in `docs/GUIDE.md` at
//! the repository root; `README.md` is the five-minute version.

pub mod benchutil;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod kernels;
pub mod kmeans;
pub mod metrics;
pub mod parallel;
pub mod rng;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod serve;
pub mod signals;
pub mod testutil;
pub mod tree;
