//! In-tree benchmark harness (criterion is not in the offline vendored
//! crate set). Used by the `rust/benches/*.rs` targets (`harness = false`).
//!
//! Provides warmup + repeated measurement with median/min reporting, an
//! environment-controlled scale knob (`REPRO_SCALE`) so `cargo bench`
//! stays tractable, and a CSV sink under `results/`.

use std::path::PathBuf;
use std::time::{Duration, Instant};

/// Dataset scale factor for benches: `REPRO_SCALE` env var, default 0.05.
/// (Scale 1.0 = the paper's dataset sizes; see the `scale` row of the
/// config-key table in docs/GUIDE.md.)
pub fn bench_scale() -> f64 {
    std::env::var("REPRO_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.05)
}

/// Repeat count for timed sections: `REPRO_REPEATS`, default 3.
pub fn bench_repeats() -> usize {
    std::env::var("REPRO_REPEATS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3)
}

/// Measure a closure `repeats` times (after one warmup) and return all
/// durations, sorted ascending.
pub fn measure<F: FnMut()>(repeats: usize, mut f: F) -> Vec<Duration> {
    f(); // warmup
    let mut times = Vec::with_capacity(repeats);
    for _ in 0..repeats {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    times.sort_unstable();
    times
}

/// Median of a sorted duration slice.
pub fn median(sorted: &[Duration]) -> Duration {
    if sorted.is_empty() {
        return Duration::ZERO;
    }
    sorted[sorted.len() / 2]
}

/// A simple CSV sink under `results/`.
pub struct CsvSink {
    path: PathBuf,
    rows: Vec<String>,
}

impl CsvSink {
    pub fn new(name: &str, header: &str) -> CsvSink {
        CsvSink {
            path: PathBuf::from("results").join(name),
            rows: vec![header.to_string()],
        }
    }

    pub fn row(&mut self, row: String) {
        self.rows.push(row);
    }

    /// Write the collected rows atomically; also echoes the path to
    /// stdout. A write failure panics — a bench whose results CSV cannot
    /// be written must fail, not print timings and quietly drop the
    /// artifact the CI run uploads.
    pub fn flush(&self) {
        let write = || -> anyhow::Result<()> {
            if let Some(dir) = self.path.parent() {
                std::fs::create_dir_all(dir)?;
            }
            let mut buf = String::new();
            for r in &self.rows {
                buf.push_str(r);
                buf.push('\n');
            }
            crate::data::io::atomic_write(&self.path, buf.as_bytes())
        };
        if let Err(e) = write() {
            panic!("could not write bench results {}: {e:#}", self.path.display());
        }
        println!("[csv] wrote {}", self.path.display());
    }
}

/// Format a duration in adaptive units.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.0}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_returns_sorted() {
        let times = measure(5, || std::thread::sleep(Duration::from_micros(10)));
        assert_eq!(times.len(), 5);
        for w in times.windows(2) {
            assert!(w[0] <= w[1]);
        }
        assert!(median(&times) >= Duration::from_micros(5));
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.00s");
        assert_eq!(fmt_duration(Duration::from_millis(5)), "5.00ms");
        assert_eq!(fmt_duration(Duration::from_micros(7)), "7us");
    }

    #[test]
    fn scale_default() {
        // Does not assert the exact value (env may be set by the runner),
        // only sanity.
        let s = bench_scale();
        assert!(s > 0.0 && s <= 1.0);
    }
}
