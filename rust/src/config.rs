//! Run configuration: a small `key=value` format with file profiles.
//!
//! The offline crate set has no serde, so the launcher uses a minimal,
//! forgiving format: one `key = value` per line, `#` comments. The same
//! keys are accepted as `--key value` CLI overrides (see `main.rs`), CLI
//! taking precedence over file, file over defaults.
//!
//! # Config keys
//!
//! Every key [`RunConfig::set`] accepts, in one place (the prose
//! walkthrough lives in `docs/GUIDE.md`):
//!
//! | key | default | meaning |
//! |-----|---------|---------|
//! | `dataset` | `aloi64` | Registry name (`covermeans datasets`) or `blobs:<n>:<d>:<k>`. |
//! | `data_file` | *(empty)* | `covermeans run`: fit a `.dmat` file (written by `covermeans pack`) instead of a registry dataset; opened under `data_backend`. |
//! | `data_backend` | `ram` | How `data_file` is opened: `ram` (read fully resident), `mmap` (demand-paged), or `chunked` (bounded-memory streaming reads). Results are byte-identical across backends. |
//! | `data_chunk_rows` | `4096` | `data_backend = chunked`: rows per streamed read. Any value reproduces the in-RAM results byte for byte. |
//! | `data_resident_mb` | `0` | `data_backend = chunked`: cap (MiB) on resident chunk memory; 0 = one chunk's worth. Throttles concurrent readers without changing any result. |
//! | `init` | `auto` | Seeding: `kmeans++`, `kmeans\|\|`, or `auto` (k-means++ for resident data, k-means\|\| for file-backed sources). |
//! | `init_rounds` | `5` | k-means\|\|: oversampling rounds. |
//! | `init_oversample` | `2` | k-means\|\|: per-round expected sample size as a multiple of `k`. |
//! | `scale` | `0.05` | Dataset size relative to the paper's (1.0 = full size). |
//! | `data_seed` | `1` | Seed for the synthetic dataset generators. |
//! | `k` | `100` | Number of clusters. |
//! | `restarts` | `10` | k-means++ restarts per cell (paper protocol). |
//! | `seed` | `1000` | First init seed; restart `r` uses `seed + r`. |
//! | `threads` | all cores | **Total** worker budget of the sweep coordinator; cells run on `threads / fit_threads` workers. |
//! | `fit_threads` | `1` | Intra-fit worker threads (0 = all cores) for assignment passes, tree builds, seeding, and batch predict. Exactness-preserving: any value reproduces the single-threaded results byte for byte. |
//! | `out_dir` | `results` | Output directory for CSV reports. |
//! | `max_iter` | `200` | Iteration cap (the paper runs to convergence; this is a guard). |
//! | `tol` | `0` | Convergence tolerance on the largest center movement; 0 keeps the exact assignment-fixpoint criterion. |
//! | `switch_at` | `7` | Hybrid: iterations of Cover-means before handing off to Shallot. |
//! | `scale_factor` | `1.2` | Cover tree radius scaling factor `b` (> 1). |
//! | `min_node_size` | `100` | Cover tree: stop splitting below this many points. |
//! | `kd_leaf_size` | `100` | k-d tree leaf size (Kanungo / Pelleg-Moore). |
//! | `algorithms` | paper table order | Comma-separated algorithm list (see [`Algorithm::parse`]). |
//! | `mb_batch` | `1024` | MiniBatch: points per batch. |
//! | `mb_tol` | `1e-4` | MiniBatch: center-movement stopping tolerance. |
//! | `mb_seed` | `0xB47C4` | MiniBatch: batch-sampling seed. |
//! | `model_out` | *(empty)* | `covermeans run`: save the fitted [`crate::kmeans::KMeansModel`] to this `.kmm` path (empty = don't). |
//! | `checkpoint_path` | *(empty)* | `covermeans run`: crash-safe snapshot file (`.kmc`) for the fit; empty disables checkpointing. `--resume` continues from it bit-identically. |
//! | `checkpoint_every` | `0` | Snapshot every N iterations (0 = only at completion / on SIGINT). Needs `checkpoint_path`. |
//! | `checkpoint_secs` | `0` | Also snapshot when this many seconds passed since the last one (0 = no time trigger). |
//! | `predict_mode` | `auto` | `covermeans predict` / `serve`: query strategy — `auto`, `tree` (cover tree over the centers), or `scan` (Elkan-pruned linear scan). |
//! | `predict_auto_k` | `64` | `covermeans predict` / `serve`: `k` at or above which `predict_mode = auto` picks the cover tree over the pruned scan ([`crate::kmeans::DEFAULT_PREDICT_AUTO_K`]; tune from the measured crossover in `BENCH_5.json`). |
//! | `predict_precision` | `f64` | `covermeans predict` / `serve`: scan arithmetic — `f64` (full doubles) or `f32` (quantized SIMD scan with certified f64 fallback; labels and distances stay bit-identical to f64, see [`crate::kmeans::PredictPrecision`]). |
//! | `pin_workers` | `0` | Pin each pool worker to its own core at spawn (Linux `sched_setaffinity`; no-op elsewhere). Placement only — results are byte-identical either way. The `COVERMEANS_FORCE_SCALAR` *env var* (not a config key) similarly forces the scalar distance kernels for A/B runs. |
//! | `serve_addr` | `127.0.0.1:7464` | `covermeans serve`: listen address (`--addr` overrides; port `0` binds an ephemeral port, printed on startup). |
//! | `max_batch` | `1024` | `covermeans serve`: the batcher drains queued requests until one coalesced predict pass holds this many rows. |
//! | `batch_wait_us` | `200` | `covermeans serve`: how long (µs) the batcher waits for more requests after the first before running a short batch. |
//! | `queue_depth` | `64` | `covermeans serve`: bound of the request queue; a full queue rejects with the retryable `ERR RETRY` code instead of growing without limit. |

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::source::{SourceBackend, DEFAULT_CHUNK_ROWS};
use crate::kmeans::{
    Algorithm, InitKind, KMeansParams, PredictMode, PredictPrecision,
    DEFAULT_PREDICT_AUTO_K,
};
use crate::tree::{CoverTreeParams, KdTreeParams};

/// Everything a single experiment run needs.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Dataset name in the registry (or `blobs:<n>:<d>:<k>`).
    pub dataset: String,
    /// Dataset scale factor relative to the paper's sizes.
    pub scale: f64,
    /// Dataset generation seed.
    pub data_seed: u64,
    /// `covermeans run`: fit a `.dmat` file instead of a registry dataset
    /// (empty = use `dataset`). Written by `covermeans pack`.
    pub data_file: String,
    /// How `data_file` is opened: resident, mmapped, or chunk-streamed.
    /// Byte-identical results on every backend.
    pub data_backend: SourceBackend,
    /// `data_backend = chunked`: rows per streamed read.
    pub data_chunk_rows: usize,
    /// `data_backend = chunked`: resident-chunk budget in MiB (0 = one
    /// chunk's worth).
    pub data_resident_mb: usize,
    /// Seeding strategy (`auto` resolves by source backend).
    pub init: InitKind,
    /// k-means||: oversampling rounds.
    pub init_rounds: usize,
    /// k-means||: per-round expected sample size as a multiple of `k`.
    pub init_oversample: f64,
    /// Number of clusters.
    pub k: usize,
    /// Number of k-means++ restarts (the paper uses 10).
    pub restarts: usize,
    /// First init seed; restart r uses `seed + r`.
    pub seed: u64,
    /// Algorithms to run (paper table order by default).
    pub algorithms: Vec<Algorithm>,
    /// Shared algorithm parameters.
    pub params: KMeansParams,
    /// Total worker-thread budget for the sweep coordinator. Cells run on
    /// `threads / fit_threads` workers, so cell-level and intra-fit
    /// parallelism share one budget. With `fit_threads = 1` (the default)
    /// every job stays single-threaded like the paper's runs.
    pub threads: usize,
    /// Output directory for CSV results.
    pub out_dir: String,
    /// `covermeans run`: path to save the fitted model (`.kmm`); empty
    /// disables saving.
    pub model_out: String,
    /// `covermeans run`: crash-safe checkpoint file (`.kmc`); empty
    /// disables checkpointing. The periodic triggers live in
    /// `params.checkpoint_every` / `params.checkpoint_secs`.
    pub checkpoint_path: String,
    /// `covermeans predict` / `serve`: batch-query strategy (auto / tree /
    /// scan).
    pub predict_mode: PredictMode,
    /// `covermeans predict` / `serve`: `k` at or above which
    /// [`PredictMode::Auto`] resolves to the cover tree over the centers.
    pub predict_auto_k: usize,
    /// `covermeans predict` / `serve`: scan arithmetic (f64 default; f32
    /// is the certified quantized fast path with identical outputs).
    pub predict_precision: PredictPrecision,
    /// `covermeans serve`: listen address (host:port; port 0 = ephemeral).
    pub serve_addr: String,
    /// `covermeans serve`: max rows coalesced into one batched predict.
    pub max_batch: usize,
    /// `covermeans serve`: batcher linger (µs) after the first queued
    /// request before running a short batch.
    pub batch_wait_us: u64,
    /// `covermeans serve`: request-queue bound (full = retryable reject).
    pub queue_depth: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            dataset: "aloi64".to_string(),
            scale: 0.05,
            data_seed: 1,
            data_file: String::new(),
            data_backend: SourceBackend::Ram,
            data_chunk_rows: DEFAULT_CHUNK_ROWS,
            data_resident_mb: 0,
            init: InitKind::Auto,
            init_rounds: 5,
            init_oversample: 2.0,
            k: 100,
            restarts: 10,
            seed: 1000,
            algorithms: Algorithm::ALL.to_vec(),
            params: KMeansParams::default(),
            threads: default_threads(),
            out_dir: "results".to_string(),
            model_out: String::new(),
            checkpoint_path: String::new(),
            predict_mode: PredictMode::Auto,
            predict_auto_k: DEFAULT_PREDICT_AUTO_K,
            predict_precision: PredictPrecision::F64,
            serve_addr: "127.0.0.1:7464".to_string(),
            max_batch: 1024,
            batch_wait_us: 200,
            queue_depth: 64,
        }
    }
}

fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
}

impl RunConfig {
    /// Every key [`RunConfig::set`] understands. The CLI uses this to
    /// tell an unknown key (a typo'd flag, rejected by the command) from
    /// an invalid value for a known key (a `set` error, reported as
    /// such).
    pub const KEYS: &'static [&'static str] = &[
        "dataset",
        "scale",
        "data_seed",
        "data_file",
        "data_backend",
        "data_chunk_rows",
        "data_resident_mb",
        "init",
        "init_rounds",
        "init_oversample",
        "k",
        "restarts",
        "seed",
        "threads",
        "fit_threads",
        "out_dir",
        "model_out",
        "checkpoint_path",
        "checkpoint_every",
        "checkpoint_secs",
        "predict_mode",
        "predict_auto_k",
        "predict_precision",
        "pin_workers",
        "serve_addr",
        "max_batch",
        "batch_wait_us",
        "queue_depth",
        "max_iter",
        "tol",
        "switch_at",
        "mb_batch",
        "mb_tol",
        "mb_seed",
        "scale_factor",
        "min_node_size",
        "kd_leaf_size",
        "algorithms",
    ];

    /// Apply one `key = value` setting.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let v = value.trim();
        match key.trim() {
            "dataset" => self.dataset = v.to_string(),
            "scale" => {
                let s: f64 = v.parse().context("scale")?;
                if !(s.is_finite() && s > 0.0) {
                    bail!("scale must be a positive number, got {v:?}");
                }
                self.scale = s;
            }
            "data_seed" => self.data_seed = v.parse().context("data_seed")?,
            "data_file" => self.data_file = v.to_string(),
            "data_backend" => {
                self.data_backend = SourceBackend::parse(v).with_context(|| {
                    format!("data_backend {v:?} (expected ram, mmap or chunked)")
                })?
            }
            "data_chunk_rows" => {
                let r: usize = v.parse().context("data_chunk_rows")?;
                if r == 0 {
                    bail!("data_chunk_rows must be at least 1");
                }
                self.data_chunk_rows = r;
            }
            "data_resident_mb" => {
                self.data_resident_mb = v.parse().context("data_resident_mb")?
            }
            "init" => {
                self.init = InitKind::parse(v).with_context(|| {
                    format!("init {v:?} (expected auto, kmeans++ or kmeans||)")
                })?
            }
            "init_rounds" => {
                let r: usize = v.parse().context("init_rounds")?;
                if r == 0 {
                    bail!("init_rounds must be at least 1");
                }
                self.init_rounds = r;
            }
            "init_oversample" => {
                let o: f64 = v.parse().context("init_oversample")?;
                if !(o.is_finite() && o > 0.0) {
                    bail!("init_oversample must be a positive number, got {v:?}");
                }
                self.init_oversample = o;
            }
            "k" => {
                let k: usize = v.parse().context("k")?;
                if k == 0 {
                    bail!("k must be at least 1");
                }
                self.k = k;
            }
            "restarts" => self.restarts = v.parse().context("restarts")?,
            "seed" => self.seed = v.parse().context("seed")?,
            "threads" => self.threads = v.parse().context("threads")?,
            // Intra-fit threads (0 = all cores), served by one persistent
            // worker pool per fit/cell: assignment-phase sharding for
            // every driver (including the k-d-tree filters and
            // MiniBatch), tree construction, and k-means++ seeding.
            // Exactness-preserving: any value reproduces the
            // single-threaded results byte for byte.
            "fit_threads" => self.params.threads = v.parse().context("fit_threads")?,
            "out_dir" => self.out_dir = v.to_string(),
            "model_out" => self.model_out = v.to_string(),
            "checkpoint_path" => self.checkpoint_path = v.to_string(),
            "checkpoint_every" => {
                self.params.checkpoint_every =
                    v.parse().context("checkpoint_every")?
            }
            "checkpoint_secs" => {
                self.params.checkpoint_secs = v.parse().context("checkpoint_secs")?
            }
            "predict_mode" => {
                self.predict_mode = PredictMode::parse(v).with_context(|| {
                    format!("predict_mode {v:?} (expected auto, tree or scan)")
                })?
            }
            "predict_auto_k" => {
                let a: usize = v.parse().context("predict_auto_k")?;
                if a == 0 {
                    bail!("predict_auto_k must be at least 1 (1 = always tree)");
                }
                self.predict_auto_k = a;
            }
            "predict_precision" => {
                self.predict_precision =
                    PredictPrecision::parse(v).with_context(|| {
                        format!("predict_precision {v:?} (expected f64 or f32)")
                    })?
            }
            "pin_workers" => {
                self.params.pin_workers = match v {
                    "1" | "true" | "yes" | "on" => true,
                    "0" | "false" | "no" | "off" => false,
                    other => bail!("pin_workers must be a boolean, got {other:?}"),
                }
            }
            "serve_addr" => self.serve_addr = v.to_string(),
            "max_batch" => {
                let b: usize = v.parse().context("max_batch")?;
                if b == 0 {
                    bail!("max_batch must be at least 1");
                }
                self.max_batch = b;
            }
            "batch_wait_us" => {
                self.batch_wait_us = v.parse().context("batch_wait_us")?
            }
            "queue_depth" => {
                let q: usize = v.parse().context("queue_depth")?;
                if q == 0 {
                    bail!("queue_depth must be at least 1");
                }
                self.queue_depth = q;
            }
            "max_iter" => self.params.max_iter = v.parse().context("max_iter")?,
            "tol" => self.params.tol = v.parse().context("tol")?,
            "switch_at" => self.params.switch_at = v.parse().context("switch_at")?,
            "mb_batch" => self.params.minibatch.batch = v.parse().context("mb_batch")?,
            "mb_tol" => self.params.minibatch.tol = v.parse().context("mb_tol")?,
            "mb_seed" => self.params.minibatch.seed = v.parse().context("mb_seed")?,
            "scale_factor" => {
                self.params.cover.scale_factor = v.parse().context("scale_factor")?
            }
            "min_node_size" => {
                self.params.cover.min_node_size = v.parse().context("min_node_size")?
            }
            "kd_leaf_size" => self.params.kd.leaf_size = v.parse().context("kd_leaf_size")?,
            "algorithms" => {
                let mut algs = Vec::new();
                for name in v.split(',') {
                    let name = name.trim();
                    if name.is_empty() {
                        continue;
                    }
                    match Algorithm::parse(name) {
                        Some(a) => algs.push(a),
                        None => bail!("unknown algorithm {name:?}"),
                    }
                }
                if algs.is_empty() {
                    bail!("empty algorithm list");
                }
                self.algorithms = algs;
            }
            other => bail!("unknown config key {other:?}"),
        }
        Ok(())
    }

    /// Load `key = value` lines from a file over the current values.
    pub fn load_file(&mut self, path: &Path) -> Result<()> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {path:?}"))?;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("{path:?} line {}: expected key = value", lineno + 1))?;
            self.set(k, v)
                .with_context(|| format!("{path:?} line {}", lineno + 1))?;
        }
        Ok(())
    }

    /// Render as a sorted `key = value` listing (for logs / provenance).
    pub fn dump(&self) -> String {
        let mut m = BTreeMap::new();
        m.insert("dataset", self.dataset.clone());
        m.insert("scale", self.scale.to_string());
        m.insert("data_seed", self.data_seed.to_string());
        m.insert("data_file", self.data_file.clone());
        m.insert("data_backend", self.data_backend.name().to_string());
        m.insert("data_chunk_rows", self.data_chunk_rows.to_string());
        m.insert("data_resident_mb", self.data_resident_mb.to_string());
        m.insert("init", self.init.name().to_string());
        m.insert("init_rounds", self.init_rounds.to_string());
        m.insert("init_oversample", self.init_oversample.to_string());
        m.insert("k", self.k.to_string());
        m.insert("restarts", self.restarts.to_string());
        m.insert("seed", self.seed.to_string());
        m.insert("threads", self.threads.to_string());
        m.insert("fit_threads", self.params.threads.to_string());
        m.insert("out_dir", self.out_dir.clone());
        m.insert("model_out", self.model_out.clone());
        m.insert("checkpoint_path", self.checkpoint_path.clone());
        m.insert(
            "checkpoint_every",
            self.params.checkpoint_every.to_string(),
        );
        m.insert("checkpoint_secs", self.params.checkpoint_secs.to_string());
        m.insert("predict_mode", self.predict_mode.name().to_string());
        m.insert("predict_auto_k", self.predict_auto_k.to_string());
        m.insert(
            "predict_precision",
            self.predict_precision.name().to_string(),
        );
        m.insert(
            "pin_workers",
            if self.params.pin_workers { "1" } else { "0" }.to_string(),
        );
        m.insert("serve_addr", self.serve_addr.clone());
        m.insert("max_batch", self.max_batch.to_string());
        m.insert("batch_wait_us", self.batch_wait_us.to_string());
        m.insert("queue_depth", self.queue_depth.to_string());
        m.insert("max_iter", self.params.max_iter.to_string());
        m.insert("tol", self.params.tol.to_string());
        m.insert("switch_at", self.params.switch_at.to_string());
        m.insert("mb_batch", self.params.minibatch.batch.to_string());
        m.insert("mb_tol", self.params.minibatch.tol.to_string());
        m.insert("mb_seed", self.params.minibatch.seed.to_string());
        m.insert("scale_factor", self.params.cover.scale_factor.to_string());
        m.insert("min_node_size", self.params.cover.min_node_size.to_string());
        m.insert("kd_leaf_size", self.params.kd.leaf_size.to_string());
        m.insert(
            "algorithms",
            self.algorithms
                .iter()
                .map(|a| a.name())
                .collect::<Vec<_>>()
                .join(","),
        );
        m.iter()
            .map(|(k, v)| format!("{k} = {v}"))
            .collect::<Vec<_>>()
            .join("\n")
    }

    /// Cover tree parameters (convenience).
    pub fn cover_params(&self) -> CoverTreeParams {
        self.params.cover
    }

    pub fn kd_params(&self) -> KdTreeParams {
        self.params.kd
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_list_matches_set() {
        // Every listed key must be *known* to `set` (whatever it thinks
        // of a junk value, it must not claim the key does not exist)...
        let mut c = RunConfig::default();
        for key in RunConfig::KEYS {
            if let Err(e) = c.set(key, "@@junk@@") {
                assert!(
                    !format!("{e:#}").contains("unknown config key"),
                    "{key} is listed in KEYS but set() does not know it"
                );
            }
        }
        // ...and an unlisted key must fail as unknown.
        let err = c.set("definitely_not_a_key", "1").unwrap_err();
        assert!(format!("{err:#}").contains("unknown config key"));
    }

    #[test]
    fn set_and_dump_roundtrip() {
        let mut c = RunConfig::default();
        c.set("dataset", "istanbul").unwrap();
        c.set("k", "42").unwrap();
        c.set("algorithms", "shallot, hybrid").unwrap();
        c.set("scale_factor", "1.3").unwrap();
        c.set("tol", "1e-6").unwrap();
        c.set("mb_batch", "256").unwrap();
        c.set("mb_tol", "0.001").unwrap();
        c.set("mb_seed", "99").unwrap();
        c.set("fit_threads", "4").unwrap();
        assert_eq!(c.params.threads, 4);
        assert_eq!(c.dataset, "istanbul");
        assert_eq!(c.k, 42);
        assert_eq!(c.algorithms, vec![Algorithm::Shallot, Algorithm::Hybrid]);
        assert!((c.params.cover.scale_factor - 1.3).abs() < 1e-12);
        assert!((c.params.tol - 1e-6).abs() < 1e-18);
        assert_eq!(c.params.minibatch.batch, 256);
        assert!((c.params.minibatch.tol - 0.001).abs() < 1e-12);
        assert_eq!(c.params.minibatch.seed, 99);
        let dump = c.dump();
        assert!(dump.contains("dataset = istanbul"));
        assert!(dump.contains("algorithms = Shallot,Hybrid"));
        assert!(dump.contains("mb_batch = 256"));
        assert!(dump.contains("tol = 0.000001"));
        assert!(dump.contains("fit_threads = 4"));
    }

    #[test]
    fn rejects_unknown_key_and_algorithm() {
        let mut c = RunConfig::default();
        assert!(c.set("nope", "1").is_err());
        assert!(c.set("algorithms", "quantum").is_err());
        assert!(c.set("algorithms", "").is_err());
        assert!(c.set("predict_mode", "psychic").is_err());
    }

    #[test]
    fn model_and_predict_keys_roundtrip() {
        let mut c = RunConfig::default();
        assert_eq!(c.model_out, "");
        assert_eq!(c.predict_mode, PredictMode::Auto);
        c.set("model_out", "out/best.kmm").unwrap();
        c.set("predict_mode", "tree").unwrap();
        assert_eq!(c.model_out, "out/best.kmm");
        assert_eq!(c.predict_mode, PredictMode::Tree);
        let dump = c.dump();
        assert!(dump.contains("model_out = out/best.kmm"));
        assert!(dump.contains("predict_mode = tree"));
    }

    #[test]
    fn kernel_and_pinning_keys_roundtrip() {
        let mut c = RunConfig::default();
        assert_eq!(c.predict_precision, PredictPrecision::F64);
        assert!(!c.params.pin_workers);
        c.set("predict_precision", "f32").unwrap();
        c.set("pin_workers", "1").unwrap();
        assert_eq!(c.predict_precision, PredictPrecision::F32);
        assert!(c.params.pin_workers);
        let dump = c.dump();
        assert!(dump.contains("predict_precision = f32"));
        assert!(dump.contains("pin_workers = 1"));
        c.set("predict_precision", "double").unwrap();
        c.set("pin_workers", "off").unwrap();
        assert_eq!(c.predict_precision, PredictPrecision::F64);
        assert!(!c.params.pin_workers);
        assert!(c.set("predict_precision", "f16").is_err());
        assert!(c.set("pin_workers", "maybe").is_err());
    }

    #[test]
    fn serve_keys_roundtrip_and_validate() {
        let mut c = RunConfig::default();
        assert_eq!(c.predict_auto_k, DEFAULT_PREDICT_AUTO_K);
        assert_eq!(c.serve_addr, "127.0.0.1:7464");
        assert_eq!(c.max_batch, 1024);
        assert_eq!(c.batch_wait_us, 200);
        assert_eq!(c.queue_depth, 64);
        c.set("predict_auto_k", "16").unwrap();
        c.set("serve_addr", "0.0.0.0:9000").unwrap();
        c.set("max_batch", "256").unwrap();
        c.set("batch_wait_us", "500").unwrap();
        c.set("queue_depth", "8").unwrap();
        assert_eq!(c.predict_auto_k, 16);
        assert_eq!(c.serve_addr, "0.0.0.0:9000");
        assert_eq!(c.max_batch, 256);
        assert_eq!(c.batch_wait_us, 500);
        assert_eq!(c.queue_depth, 8);
        let dump = c.dump();
        assert!(dump.contains("predict_auto_k = 16"));
        assert!(dump.contains("serve_addr = 0.0.0.0:9000"));
        assert!(dump.contains("max_batch = 256"));
        assert!(dump.contains("batch_wait_us = 500"));
        assert!(dump.contains("queue_depth = 8"));
        // Zero bounds are rejected with a diagnosable error, not accepted
        // to wedge the daemon later.
        assert!(c.set("predict_auto_k", "0").is_err());
        assert!(c.set("max_batch", "0").is_err());
        assert!(c.set("queue_depth", "0").is_err());
        assert!(c.set("k", "0").is_err());
        assert!(c.set("scale", "-1").is_err());
        assert!(c.set("scale", "nan").is_err());
    }

    #[test]
    fn checkpoint_keys_roundtrip() {
        let mut c = RunConfig::default();
        assert_eq!(c.checkpoint_path, "");
        assert_eq!(c.params.checkpoint_every, 0);
        assert_eq!(c.params.checkpoint_secs, 0);
        c.set("checkpoint_path", "out/fit.kmc").unwrap();
        c.set("checkpoint_every", "10").unwrap();
        c.set("checkpoint_secs", "30").unwrap();
        assert_eq!(c.checkpoint_path, "out/fit.kmc");
        assert_eq!(c.params.checkpoint_every, 10);
        assert_eq!(c.params.checkpoint_secs, 30);
        let dump = c.dump();
        assert!(dump.contains("checkpoint_path = out/fit.kmc"));
        assert!(dump.contains("checkpoint_every = 10"));
        assert!(dump.contains("checkpoint_secs = 30"));
        assert!(c.set("checkpoint_every", "many").is_err());
        assert!(c.set("checkpoint_secs", "-5").is_err());
    }

    #[test]
    fn data_source_and_init_keys_roundtrip() {
        let mut c = RunConfig::default();
        assert_eq!(c.data_file, "");
        assert_eq!(c.data_backend, SourceBackend::Ram);
        assert_eq!(c.data_chunk_rows, DEFAULT_CHUNK_ROWS);
        assert_eq!(c.data_resident_mb, 0);
        assert_eq!(c.init, InitKind::Auto);
        assert_eq!(c.init_rounds, 5);
        assert!((c.init_oversample - 2.0).abs() < 1e-12);
        c.set("data_file", "big.dmat").unwrap();
        c.set("data_backend", "chunked").unwrap();
        c.set("data_chunk_rows", "512").unwrap();
        c.set("data_resident_mb", "64").unwrap();
        c.set("init", "kmeans||").unwrap();
        c.set("init_rounds", "8").unwrap();
        c.set("init_oversample", "3.5").unwrap();
        assert_eq!(c.data_file, "big.dmat");
        assert_eq!(c.data_backend, SourceBackend::Chunked);
        assert_eq!(c.data_chunk_rows, 512);
        assert_eq!(c.data_resident_mb, 64);
        assert_eq!(c.init, InitKind::Parallel);
        assert_eq!(c.init_rounds, 8);
        assert!((c.init_oversample - 3.5).abs() < 1e-12);
        let dump = c.dump();
        assert!(dump.contains("data_file = big.dmat"));
        assert!(dump.contains("data_backend = chunked"));
        assert!(dump.contains("data_chunk_rows = 512"));
        assert!(dump.contains("data_resident_mb = 64"));
        assert!(dump.contains("init = kmeans||"));
        assert!(dump.contains("init_rounds = 8"));
        assert!(dump.contains("init_oversample = 3.5"));
        // Bad values fail with diagnosable errors.
        assert!(c.set("data_backend", "floppy").is_err());
        assert!(c.set("data_chunk_rows", "0").is_err());
        assert!(c.set("init", "psychic").is_err());
        assert!(c.set("init_rounds", "0").is_err());
        assert!(c.set("init_oversample", "-1").is_err());
        assert!(c.set("init_oversample", "nan").is_err());
    }

    #[test]
    fn load_file_with_comments() {
        let mut c = RunConfig::default();
        let dir = std::env::temp_dir();
        let p = dir.join(format!("covermeans_cfg_{}.conf", std::process::id()));
        std::fs::write(&p, "# profile\nk = 7 # clusters\n\ndataset = kdd04\n").unwrap();
        c.load_file(&p).unwrap();
        assert_eq!(c.k, 7);
        assert_eq!(c.dataset, "kdd04");
    }

    #[test]
    fn load_file_reports_bad_line() {
        let mut c = RunConfig::default();
        let dir = std::env::temp_dir();
        let p = dir.join(format!("covermeans_badcfg_{}.conf", std::process::id()));
        std::fs::write(&p, "k 7\n").unwrap();
        assert!(c.load_file(&p).is_err());
    }
}
