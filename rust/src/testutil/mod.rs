//! Minimal property-based testing support (no `proptest` in the offline
//! vendored crate set).
//!
//! [`check`] runs a closure over `cases` deterministic pseudo-random seeds
//! and, on failure, re-raises with the failing case index and seed so the
//! case can be replayed (`CASE_SEED` env var narrows a run to one seed).

use crate::rng::Rng;

/// Configuration for a property check.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: u32,
    /// Base seed; each case derives its own stream.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 32, seed: 0xC07E_C0DE }
    }
}

/// Run `prop` for `cfg.cases` generated seeds. The closure receives a
/// per-case RNG and should panic (assert) on property violation.
pub fn check<F: FnMut(&mut Rng)>(cfg: Config, name: &str, mut prop: F) {
    // Replay support: CASE_SEED=<u64> runs exactly one case.
    if let Ok(s) = std::env::var("CASE_SEED") {
        if let Ok(seed) = s.parse::<u64>() {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
            return;
        }
    }
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(case as u64 + 1));
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng)
        }));
        if let Err(e) = result {
            eprintln!(
                "property {name:?} failed at case {case}/{}; replay with CASE_SEED={case_seed}",
                cfg.cases
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Uniform choice helpers for property generators.
pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

/// Shared corruption fixtures for the checksummed binary container
/// formats (`.kmm` models, `.kmc` checkpoints, `.dmat` data files). Every
/// format-specific test used to hand-roll the same faults; this harness
/// drives a parser through the canonical set once — truncation, single-bit
/// flips, a clobbered magic, trailing garbage, and alien bytes — so a new
/// format buys the whole battery with one call.
pub mod corruption {
    /// Run the canonical fault set against `parse` given the pristine
    /// serialized `bytes`. `checked_len` is the checksummed prefix of the
    /// container — `bytes.len()` when the checksum covers everything (as
    /// in `.kmm`/`.kmc`), the header length when only the header is
    /// self-validating (as in `.dmat`, whose payload is guarded by the
    /// exact-length contract instead). Requirements enforced:
    ///
    /// - pristine bytes parse;
    /// - every truncation fails (never panics);
    /// - a clobbered magic byte fails naming the magic or the checksum;
    /// - any single-bit flip inside `checked_len` fails naming the
    ///   checksum (or the magic, when the flip lands in it);
    /// - trailing garbage fails (the checksum moves or the length lies);
    /// - bytes from another format entirely fail.
    pub fn assert_rejects_faults<T, E: std::fmt::Display>(
        format: &str,
        bytes: &[u8],
        checked_len: usize,
        mut parse: impl FnMut(&[u8]) -> Result<T, E>,
    ) {
        assert!(
            (16..=bytes.len()).contains(&checked_len),
            "{format}: checked_len {checked_len} outside 16..={}",
            bytes.len()
        );
        if let Err(e) = parse(bytes) {
            panic!("{format}: pristine bytes must parse: {e:#}");
        }
        // Truncation at structural boundaries and arbitrary cuts.
        let n = bytes.len();
        for cut in [0, 2, 6, n / 4, n / 2, n.saturating_sub(9), n - 1] {
            if cut >= n {
                continue;
            }
            if parse(&bytes[..cut]).is_ok() {
                panic!("{format}: prefix of {cut}/{n} bytes must not parse");
            }
        }
        // A clobbered magic is named as such (or trips the checksum when
        // the magic sits inside the checksummed region).
        let mut bad = bytes.to_vec();
        bad[0] ^= 0x11;
        expect_integrity_error(format, "clobbered magic", parse(&bad));
        // Single-bit flips inside the checksummed prefix: front, middle,
        // the stored checksum itself, and just before it.
        for (at, mask) in [
            (4, 0x01u8),
            (checked_len / 2, 0x40),
            (checked_len - 1, 0x80),
            (checked_len - 12, 0x01),
        ] {
            let mut bad = bytes.to_vec();
            bad[at] ^= mask;
            expect_integrity_error(
                format,
                &format!("bit flip at byte {at}"),
                parse(&bad),
            );
        }
        // Trailing garbage.
        let mut long = bytes.to_vec();
        long.extend_from_slice(&[0u8; 16]);
        if parse(&long).is_ok() {
            panic!("{format}: trailing garbage must not parse");
        }
        // Not this format at all.
        if parse(b"FMAT1\n2 2\n....").is_ok() {
            panic!("{format}: alien bytes must not parse");
        }
    }

    fn expect_integrity_error<T, E: std::fmt::Display>(
        format: &str,
        fault: &str,
        result: Result<T, E>,
    ) {
        match result {
            Ok(_) => panic!("{format}: {fault} must not parse"),
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(
                    msg.contains("checksum") || msg.contains("magic"),
                    "{format}: {fault} failed for the wrong reason: {msg}"
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check(Config { cases: 10, seed: 1 }, "count", |_rng| n += 1);
        assert_eq!(n, 10);
    }

    #[test]
    fn distinct_case_seeds() {
        let mut seen = Vec::new();
        check(Config { cases: 5, seed: 2 }, "seeds", |rng| {
            seen.push(rng.next_u64());
        });
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 5);
    }

    #[test]
    fn usize_in_bounds() {
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let v = usize_in(&mut rng, 5, 9);
            assert!((5..=9).contains(&v));
        }
    }
}
