//! Minimal property-based testing support (no `proptest` in the offline
//! vendored crate set).
//!
//! [`check`] runs a closure over `cases` deterministic pseudo-random seeds
//! and, on failure, re-raises with the failing case index and seed so the
//! case can be replayed (`CASE_SEED` env var narrows a run to one seed).

use crate::rng::Rng;

/// Configuration for a property check.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: u32,
    /// Base seed; each case derives its own stream.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 32, seed: 0xC07E_C0DE }
    }
}

/// Run `prop` for `cfg.cases` generated seeds. The closure receives a
/// per-case RNG and should panic (assert) on property violation.
pub fn check<F: FnMut(&mut Rng)>(cfg: Config, name: &str, mut prop: F) {
    // Replay support: CASE_SEED=<u64> runs exactly one case.
    if let Ok(s) = std::env::var("CASE_SEED") {
        if let Ok(seed) = s.parse::<u64>() {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
            return;
        }
    }
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(0x9E37_79B9_7F4A_7C15u64.wrapping_mul(case as u64 + 1));
        let mut rng = Rng::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng)
        }));
        if let Err(e) = result {
            eprintln!(
                "property {name:?} failed at case {case}/{}; replay with CASE_SEED={case_seed}",
                cfg.cases
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// Uniform choice helpers for property generators.
pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check(Config { cases: 10, seed: 1 }, "count", |_rng| n += 1);
        assert_eq!(n, 10);
    }

    #[test]
    fn distinct_case_seeds() {
        let mut seen = Vec::new();
        check(Config { cases: 5, seed: 2 }, "seeds", |rng| {
            seen.push(rng.next_u64());
        });
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 5);
    }

    #[test]
    fn usize_in_bounds() {
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let v = usize_in(&mut rng, 5, 9);
            assert!((5..=9).contains(&v));
        }
    }
}
