//! Measurement substrate: the paper's evaluation metrics.
//!
//! The paper reports two per-algorithm quantities, both *relative to the
//! Standard algorithm*: the number of Euclidean distance computations
//! (Tables 2, Fig. 1a) and wall-clock run time (Tables 3-4, Figs. 1b, 2).
//! `DistCounter` is the single funnel through which all algorithm code
//! computes distances, so the counts are exact and backend-independent;
//! `IterationLog` captures the cumulative per-iteration series of Fig. 1.

pub mod quality;

use std::time::{Duration, Instant};

use crate::kernels;

/// Counted distance oracle. Every Euclidean distance (or squared distance)
/// an algorithm evaluates goes through this; one evaluation = one count,
/// matching how ELKI's benchmark counts them (inter-center distances and
/// center-movement distances included). The arithmetic itself is the
/// runtime-dispatched kernel of [`crate::kernels`] — bit-identical to the
/// scalar reference under every dispatch.
#[derive(Debug, Default, Clone)]
pub struct DistCounter {
    count: u64,
}

impl DistCounter {
    pub fn new() -> Self {
        DistCounter { count: 0 }
    }

    /// Euclidean distance, counted.
    #[inline]
    pub fn d(&mut self, a: &[f64], b: &[f64]) -> f64 {
        self.count += 1;
        kernels::dist(a, b)
    }

    /// Squared Euclidean distance, counted once (a squared distance is the
    /// same loop; algorithms that compare squared values avoid the sqrt but
    /// still pay the O(d) pass the paper counts).
    #[inline]
    pub fn sq(&mut self, a: &[f64], b: &[f64]) -> f64 {
        self.count += 1;
        kernels::sqdist(a, b)
    }

    /// Record `n` distance computations performed in a batched kernel
    /// (the [`crate::kernels`] argmin scans, the XLA assign path).
    #[inline]
    pub fn add_bulk(&mut self, n: u64) {
        self.count += n;
    }

    #[inline]
    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn reset(&mut self) {
        self.count = 0;
    }
}

/// One row of the Fig. 1 series: state *after* iteration `iter`.
#[derive(Debug, Clone, PartialEq)]
pub struct IterationStat {
    pub iter: usize,
    /// Cumulative distance computations up to and including this iteration.
    pub dist_cum: u64,
    /// Cumulative elapsed time (excludes tree construction; Fig. 1 does).
    pub time_cum: Duration,
    /// Number of points whose assignment changed this iteration.
    pub changed: usize,
}

/// Per-run iteration series.
#[derive(Debug, Default, Clone)]
pub struct IterationLog {
    pub stats: Vec<IterationStat>,
}

impl IterationLog {
    pub fn new() -> Self {
        IterationLog { stats: Vec::new() }
    }

    pub fn push(&mut self, iter: usize, dist_cum: u64, time_cum: Duration, changed: usize) {
        self.stats.push(IterationStat { iter, dist_cum, time_cum, changed });
    }

    pub fn len(&self) -> usize {
        self.stats.len()
    }

    pub fn is_empty(&self) -> bool {
        self.stats.is_empty()
    }
}

/// Simple monotonic stopwatch.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

/// SSE of an assignment against its centers — the single implementation
/// behind `RunResult::sse` and the driver API's snapshot inertia
/// (uncounted: evaluation work, not algorithm work).
pub fn sse(data: &crate::data::Matrix, labels: &[u32], centers: &crate::data::Matrix) -> f64 {
    sse_src(data.into(), labels, centers)
}

/// [`sse`] over any data source backend: one sequential canonical-order
/// pass, so the result is bit-identical across in-RAM, mmap, and chunked
/// sources.
pub fn sse_src(
    src: crate::data::SourceView<'_>,
    labels: &[u32],
    centers: &crate::data::Matrix,
) -> f64 {
    let cols = src.cols();
    let mut sse = 0.0;
    src.visit(0..labels.len(), |start, block| {
        for (off, p) in block.chunks_exact(cols).enumerate() {
            sse += kernels::sqdist(p, centers.row(labels[start + off] as usize));
        }
    });
    sse
}

/// Outcome of one k-means run (all algorithms return this shape).
#[derive(Debug, Clone)]
pub struct RunResult {
    /// Final assignment, one cluster index per point.
    pub labels: Vec<u32>,
    /// Final cluster centers (k x d).
    pub centers: crate::data::Matrix,
    /// Iterations until convergence (assignment fixpoint) or the cap.
    pub iterations: usize,
    /// Total distance computations (excludes index construction; see
    /// `build_dist` for those, as the paper separates them in Fig. 1).
    pub distances: u64,
    /// Distance computations spent building the tree index (0 for
    /// non-tree algorithms).
    pub build_dist: u64,
    /// Algorithm time excluding index construction.
    pub time: Duration,
    /// Index construction time (0 for non-tree algorithms).
    pub build_time: Duration,
    /// Per-iteration series for Fig. 1.
    pub log: IterationLog,
    /// Whether the run reached the assignment fixpoint before the cap.
    pub converged: bool,
}

impl RunResult {
    /// Sum of squared errors of the final clustering, computed fresh
    /// (not counted: it is an evaluation quantity, not algorithm work).
    pub fn sse(&self, data: &crate::data::Matrix) -> f64 {
        sse(data, &self.labels, &self.centers)
    }

    /// Total time including index construction (Tables 3-4 include it).
    pub fn total_time(&self) -> Duration {
        self.time + self.build_time
    }

    /// Total distance computations including index construction.
    pub fn total_distances(&self) -> u64 {
        self.distances + self.build_dist
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_counts() {
        let mut c = DistCounter::new();
        let a = [0.0, 0.0];
        let b = [3.0, 4.0];
        assert_eq!(c.d(&a, &b), 5.0);
        assert_eq!(c.sq(&a, &b), 25.0);
        c.add_bulk(10);
        assert_eq!(c.count(), 12);
        c.reset();
        assert_eq!(c.count(), 0);
    }

    #[test]
    fn iteration_log_series() {
        let mut log = IterationLog::new();
        log.push(1, 100, Duration::from_millis(5), 50);
        log.push(2, 150, Duration::from_millis(9), 3);
        assert_eq!(log.len(), 2);
        assert!(log.stats[1].dist_cum >= log.stats[0].dist_cum);
    }

    #[test]
    fn run_result_sse() {
        use crate::data::Matrix;
        let data = Matrix::from_rows(&[&[0.0], &[2.0]]);
        let centers = Matrix::from_rows(&[&[1.0]]);
        let r = RunResult {
            labels: vec![0, 0],
            centers,
            iterations: 1,
            distances: 2,
            build_dist: 0,
            time: Duration::ZERO,
            build_time: Duration::ZERO,
            log: IterationLog::new(),
            converged: true,
        };
        assert_eq!(r.sse(&data), 2.0);
    }
}
