//! Clustering quality criteria for choosing k (paper §4, Table 4: "the
//! 'best' clustering can be chosen by a heuristic such as the 'Elbow'
//! method, or any of the better alternatives [19]").
//!
//! Implemented: SSE (the k-means objective), the Calinski-Harabasz
//! variance-ratio criterion, the simplified silhouette, and the BIC score
//! under a spherical Gaussian model — the standard "better alternatives"
//! family. All evaluate a finished clustering; none is counted against the
//! algorithm's distance budget (they are evaluation work).

use crate::data::Matrix;
use crate::kernels::{argmin2, sqdist};

/// Sum of squared errors (the k-means objective; lower is better).
pub fn sse(data: &Matrix, labels: &[u32], centers: &Matrix) -> f64 {
    labels
        .iter()
        .enumerate()
        .map(|(i, &l)| sqdist(data.row(i), centers.row(l as usize)))
        .sum()
}

/// Calinski-Harabasz variance-ratio criterion (higher is better):
/// `(B / (k-1)) / (W / (n-k))` with between/within-cluster dispersion.
pub fn calinski_harabasz(data: &Matrix, labels: &[u32], centers: &Matrix) -> f64 {
    let n = data.rows();
    let k = centers.rows();
    if k <= 1 || n <= k {
        return f64::NAN;
    }
    let d = data.cols();
    // Global mean.
    let mut mean = vec![0.0; d];
    for row in data.iter_rows() {
        for j in 0..d {
            mean[j] += row[j];
        }
    }
    for m in mean.iter_mut() {
        *m /= n as f64;
    }
    // Cluster sizes.
    let mut sizes = vec![0usize; k];
    for &l in labels {
        sizes[l as usize] += 1;
    }
    let between: f64 = (0..k)
        .map(|c| sizes[c] as f64 * sqdist(centers.row(c), &mean))
        .sum();
    let within = sse(data, labels, centers);
    if within <= 0.0 {
        return f64::INFINITY;
    }
    (between / (k - 1) as f64) / (within / (n - k) as f64)
}

/// Simplified silhouette (higher is better, in [-1, 1]): per point,
/// `a` = distance to own center, `b` = distance to the nearest other
/// center; silhouette = (b - a) / max(a, b). O(n k) but centroid-based
/// (the full silhouette is O(n^2) and impractical at the paper's sizes).
pub fn simplified_silhouette(data: &Matrix, labels: &[u32], centers: &Matrix) -> f64 {
    let n = data.rows();
    let k = centers.rows();
    if k <= 1 || n == 0 {
        return f64::NAN;
    }
    let mut total = 0.0;
    for (i, &l) in labels.iter().enumerate() {
        let p = data.row(i);
        let a = sqdist(p, centers.row(l as usize)).sqrt();
        // One batched argmin2 scan instead of a hand-rolled min loop: if
        // the nearest center is the point's own, the nearest *other* is
        // the second-nearest; otherwise it is the nearest itself (the
        // min over c != l then includes c1). Same distances, same min.
        let (c1, d1, _, d2) = argmin2(p, centers);
        let b = if c1 == l { d2 } else { d1 };
        let m = a.max(b);
        total += if m > 0.0 { (b - a) / m } else { 0.0 };
    }
    total / n as f64
}

/// BIC under identical spherical Gaussians (X-means style; higher is
/// better): log-likelihood minus `0.5 * p * ln n` with `p = k*(d+1)`
/// free parameters.
pub fn bic(data: &Matrix, labels: &[u32], centers: &Matrix) -> f64 {
    let n = data.rows();
    let k = centers.rows();
    let d = data.cols() as f64;
    if n <= k {
        return f64::NAN;
    }
    let rss = sse(data, labels, centers);
    // MLE of the shared spherical variance.
    let var = (rss / ((n - k) as f64 * d)).max(1e-300);
    let mut sizes = vec![0usize; k];
    for &l in labels {
        sizes[l as usize] += 1;
    }
    let nf = n as f64;
    let mut loglik = 0.0;
    for &sz in &sizes {
        if sz > 0 {
            let szf = sz as f64;
            loglik += szf * (szf / nf).ln();
        }
    }
    loglik += -0.5 * nf * d * (2.0 * std::f64::consts::PI * var).ln()
        - 0.5 * (nf - k as f64) * d;
    let params = k as f64 * (d + 1.0);
    loglik - 0.5 * params * nf.ln()
}

/// Pick the best k from `(k, labels, centers)` candidates by a criterion.
pub fn choose_k<'a, I>(data: &Matrix, candidates: I, criterion: Criterion) -> Option<usize>
where
    I: IntoIterator<Item = (usize, &'a [u32], &'a Matrix)>,
{
    let mut best: Option<(usize, f64)> = None;
    for (k, labels, centers) in candidates {
        let score = match criterion {
            Criterion::CalinskiHarabasz => calinski_harabasz(data, labels, centers),
            Criterion::SimplifiedSilhouette => {
                simplified_silhouette(data, labels, centers)
            }
            Criterion::Bic => bic(data, labels, centers),
        };
        if score.is_nan() {
            continue;
        }
        if best.map(|(_, s)| score > s).unwrap_or(true) {
            best = Some((k, score));
        }
    }
    best.map(|(k, _)| k)
}

/// Criterion selector for [`choose_k`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Criterion {
    CalinskiHarabasz,
    SimplifiedSilhouette,
    Bic,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{self, init, Algorithm, KMeansParams, Workspace};
    use crate::metrics::DistCounter;

    fn cluster(data: &Matrix, k: usize) -> (Vec<u32>, Matrix) {
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(data, k, 5, &mut dc);
        let r = kmeans::run(
            data,
            &init_c,
            &KMeansParams::with_algorithm(Algorithm::Hybrid),
            &mut Workspace::new(),
        );
        (r.labels, r.centers)
    }

    #[test]
    fn criteria_prefer_true_k_on_separated_blobs() {
        let true_k = 4;
        let data = synth::gaussian_blobs(600, 3, true_k, 0.08, 41);
        let mut results = Vec::new();
        for k in [2usize, 3, 4, 6, 8] {
            results.push((k, cluster(&data, k)));
        }
        let cands: Vec<(usize, &[u32], &Matrix)> = results
            .iter()
            .map(|(k, (l, c))| (*k, l.as_slice(), c))
            .collect();
        let ch = choose_k(&data, cands.iter().map(|&(k, l, c)| (k, l, c)),
                          Criterion::CalinskiHarabasz);
        let sil = choose_k(&data, cands.iter().map(|&(k, l, c)| (k, l, c)),
                           Criterion::SimplifiedSilhouette);
        assert_eq!(ch, Some(true_k), "CH should find the true k");
        assert_eq!(sil, Some(true_k), "silhouette should find the true k");
    }

    #[test]
    fn silhouette_bounds() {
        let data = synth::gaussian_blobs(200, 2, 3, 0.1, 43);
        let (labels, centers) = cluster(&data, 3);
        let s = simplified_silhouette(&data, &labels, &centers);
        assert!((-1.0..=1.0).contains(&s));
        assert!(s > 0.5, "well-separated blobs should score high, got {s}");
    }

    #[test]
    fn degenerate_cases_are_nan() {
        let data = synth::gaussian_blobs(50, 2, 2, 0.5, 44);
        let (labels, centers) = cluster(&data, 1);
        assert!(calinski_harabasz(&data, &labels, &centers).is_nan());
        assert!(simplified_silhouette(&data, &labels, &centers).is_nan());
    }

    #[test]
    fn sse_matches_runresult() {
        let data = synth::gaussian_blobs(100, 2, 3, 0.4, 45);
        let mut dc = DistCounter::new();
        let init_c = init::kmeans_plus_plus(&data, 3, 6, &mut dc);
        let r = kmeans::run(
            &data,
            &init_c,
            &KMeansParams::default(),
            &mut Workspace::new(),
        );
        assert!((r.sse(&data) - sse(&data, &r.labels, &r.centers)).abs() < 1e-9);
    }
}
