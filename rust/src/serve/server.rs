//! The resident daemon: accept loop, coalescing batcher, atomic
//! hot-reload, and graceful drain.
//!
//! Data flow:
//!
//! ```text
//! accept loop ──spawn──▶ connection handlers ──try_send──▶ bounded queue
//!                                                              │
//!                              per-job reply channel ◀── batcher thread
//!                                                     (one warm Parallelism
//!                                                      pool, one predict_par
//!                                                      pass per batch)
//! ```
//!
//! The queue is a [`std::sync::mpsc::sync_channel`] of depth
//! `queue_depth`: when the batcher falls behind, `try_send` fails fast
//! and the handler answers `ERR RETRY` instead of buffering without
//! bound — that refusal *is* the backpressure contract. The batcher
//! drains up to `max_batch` rows or waits `batch_wait_us` after the
//! first job, whichever ends first, then runs a single
//! [`KMeansModel::predict_par_with`] pass and scatters the label /
//! distance slices back to each connection.
//!
//! Hot-reload (`RELOAD` verb or SIGHUP) re-reads the model file and
//! swaps an `Arc<KMeansModel>` behind an [`RwLock`] **only after** the
//! bytes parse and their checksum verifies ([`KMeansModel::from_bytes`]
//! rejects corrupt or truncated files), so a bad file on disk can never
//! change served output. Each reply carries the serving model's checksum
//! as a version tag, so clients observe exactly when a swap landed.

use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::protocol::{
    self, ErrCode, PredictRequest, MAX_REQUEST_ROWS, PROTOCOL_VERSION,
};
use super::stats::ServeStats;
use crate::data::Matrix;
use crate::kmeans::{KMeansModel, PredictMode, PredictOptions, PredictPrecision};
use crate::parallel::Parallelism;

/// How a [`Server`] is built; the CLI fills this from [`crate::config`]
/// keys, tests construct it directly.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// The `.kmm` file served — also the hot-reload source.
    pub model_path: PathBuf,
    /// Bind address (`HOST:PORT`; port `0` picks an ephemeral port).
    pub addr: String,
    /// Max rows coalesced into one predict pass (config `max_batch`).
    pub max_batch: usize,
    /// How long the batcher waits after the first queued job for more
    /// rows to coalesce, in microseconds (config `batch_wait_us`).
    pub batch_wait_us: u64,
    /// Bound of the handler→batcher job queue (config `queue_depth`);
    /// a full queue rejects with `ERR RETRY`.
    pub queue_depth: usize,
    /// Query strategy (config `predict_mode`).
    pub mode: PredictMode,
    /// [`PredictMode::Auto`] cutoff (config `predict_auto_k`).
    pub auto_k: usize,
    /// Worker threads of the daemon-lifetime pool (config `threads`;
    /// 0 = all cores). Labels are thread-count invariant.
    pub threads: usize,
    /// Scan arithmetic (config `predict_precision`). [`PredictPrecision::F32`]
    /// serves from quantized centers with a certified exact-fallback path;
    /// labels and distances stay identical to f64 serving.
    pub precision: PredictPrecision,
    /// Pin pool workers to distinct cores (config `pin_workers`;
    /// Linux-only, a no-op elsewhere). Placement only — never results.
    pub pin_workers: bool,
    /// Register SIGHUP (reload) and SIGINT/SIGTERM (shutdown) handlers.
    /// Only the CLI sets this — signal handlers are process-global, so
    /// in-process tests must leave it off.
    pub install_signal_handlers: bool,
}

impl ServeConfig {
    /// A config for in-process tests: ephemeral port, no signal
    /// handlers, everything else from the given knobs.
    pub fn for_tests(model_path: PathBuf) -> ServeConfig {
        ServeConfig {
            model_path,
            addr: "127.0.0.1:0".to_string(),
            max_batch: 1024,
            batch_wait_us: 200,
            queue_depth: 64,
            mode: PredictMode::Auto,
            auto_k: crate::kmeans::DEFAULT_PREDICT_AUTO_K,
            threads: 1,
            precision: PredictPrecision::F64,
            pin_workers: false,
            install_signal_handlers: false,
        }
    }

    /// The [`PredictOptions`] every batch and prewarm of this daemon uses.
    fn predict_options(&self) -> PredictOptions {
        PredictOptions {
            mode: self.mode,
            auto_k: self.auto_k,
            threads: self.threads,
            precision: self.precision,
        }
    }
}

/// One queued predict job plus the channel its reply scatters back on.
struct Job {
    rows: Vec<f64>,
    n: usize,
    dim: usize,
    reply: mpsc::Sender<BatchReply>,
}

/// What the batcher hands back to a waiting connection handler.
enum BatchReply {
    Ok {
        labels: Vec<u32>,
        distances: Vec<f64>,
        checksum: u64,
        mode: PredictMode,
    },
    /// The serving model changed dimensionality between the handler's
    /// check and the batch run (a hot-reload race); the handler turns
    /// this into `ERR BADDIM`.
    WrongDim { expected: usize },
}

/// State shared by the accept loop, handlers, and batcher.
struct Shared {
    cfg: ServeConfig,
    /// The serving model; reload writes, everything else read-clones the
    /// `Arc` (the pointer-swap that makes reload atomic).
    model: RwLock<Arc<KMeansModel>>,
    stats: ServeStats,
    shutdown: AtomicBool,
    /// Live connection handlers (drain barrier for graceful shutdown).
    conns: AtomicUsize,
    /// Producer side of the job queue; `None` once draining has begun,
    /// so late requests fail fast with `ERR RETRY`.
    queue: Mutex<Option<SyncSender<Job>>>,
}

impl Shared {
    fn current_model(&self) -> Arc<KMeansModel> {
        self.model.read().unwrap().clone()
    }

    /// Re-read `model_path`; swap only if the bytes parse and verify.
    /// When the primary file is rejected, fall back to the `.prev`
    /// generation retained by the atomic model writer
    /// ([`crate::data::io::atomic_write`]) — checkpoint-style: the daemon
    /// serves a verified generation or keeps the in-memory one, never a
    /// torn file.
    fn reload(&self) -> Result<u64> {
        let attempt = |path: &std::path::Path| -> Result<Arc<KMeansModel>> {
            let bytes = std::fs::read(path)
                .with_context(|| format!("read model {path:?}"))?;
            let model = KMeansModel::from_bytes(&bytes)?;
            Ok(Arc::new(model))
        };
        let (model, fallback) = match attempt(&self.cfg.model_path) {
            Ok(m) => (m, false),
            Err(primary_err) => {
                let prev =
                    crate::data::io::sibling_path(&self.cfg.model_path, ".prev");
                match attempt(&prev) {
                    Ok(m) => {
                        eprintln!(
                            "serve: reload candidate rejected ({primary_err:#}); \
                             serving retained generation {prev:?}"
                        );
                        (m, true)
                    }
                    Err(_) => {
                        ServeStats::bump(&self.stats.reload_fail);
                        return Err(primary_err);
                    }
                }
            }
        };
        let prep = model.prewarm_opts(&self.cfg.predict_options());
        ServeStats::add(&self.stats.prep_evals, prep);
        let sum = model.checksum();
        *self.model.write().unwrap() = model;
        ServeStats::bump(if fallback {
            &self.stats.reload_fallback
        } else {
            &self.stats.reload_ok
        });
        Ok(sum)
    }
}

/// A running daemon. [`Server::start`] binds and spawns the threads;
/// [`Server::wait`] blocks until shutdown and drains; dropping the
/// handle shuts down too.
pub struct Server {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept_thread: Option<JoinHandle<()>>,
    batch_thread: Option<JoinHandle<()>>,
}

impl Server {
    /// Load the model, prewarm the serving index, bind, and start
    /// serving. Returns once the listener is live (`addr()` is final).
    pub fn start(cfg: ServeConfig) -> Result<Server> {
        let model = Arc::new(
            KMeansModel::load(&cfg.model_path)
                .with_context(|| format!("load model {:?}", cfg.model_path))?,
        );
        let stats = ServeStats::new();
        let prep = model.prewarm_opts(&cfg.predict_options());
        ServeStats::add(&stats.prep_evals, prep);

        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("bind {:?}", cfg.addr))?;
        let addr = listener.local_addr().context("listener local_addr")?;
        listener
            .set_nonblocking(true)
            .context("set listener nonblocking")?;

        if cfg.install_signal_handlers {
            signals::install();
        }

        let (tx, rx) = mpsc::sync_channel::<Job>(cfg.queue_depth.max(1));
        let shared = Arc::new(Shared {
            cfg,
            model: RwLock::new(model),
            stats,
            shutdown: AtomicBool::new(false),
            conns: AtomicUsize::new(0),
            queue: Mutex::new(Some(tx)),
        });

        let batch_thread = {
            let shared = shared.clone();
            thread::Builder::new()
                .name("serve-batcher".to_string())
                .spawn(move || batcher_loop(&shared, rx))
                .context("spawn batcher thread")?
        };
        let accept_thread = {
            let shared = shared.clone();
            thread::Builder::new()
                .name("serve-accept".to_string())
                .spawn(move || accept_loop(&shared, listener))
                .context("spawn accept thread")?
        };

        Ok(Server {
            shared,
            addr,
            accept_thread: Some(accept_thread),
            batch_thread: Some(batch_thread),
        })
    }

    /// The bound address (resolves port `0` to the ephemeral pick).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Checksum (version tag) of the model currently serving.
    pub fn model_checksum(&self) -> u64 {
        self.shared.current_model().checksum()
    }

    /// JSON snapshot of the daemon counters.
    pub fn stats_json(&self) -> String {
        self.shared.stats.snapshot_json()
    }

    /// Trigger a hot-reload (same path as the `RELOAD` verb / SIGHUP).
    pub fn reload(&self) -> Result<u64> {
        self.shared.reload()
    }

    /// Ask the daemon to stop; returns immediately. Pair with
    /// [`Server::wait`] to block until the drain completes.
    pub fn request_shutdown(&self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
    }

    /// Block until shutdown is requested (flag, signal, or `SHUTDOWN`
    /// verb), then drain: stop accepting, let in-flight handlers get
    /// their batched replies, and join the batcher.
    pub fn wait(&mut self) -> Result<()> {
        if let Some(t) = self.accept_thread.take() {
            t.join().ok();
        }
        // Handlers still hold queue senders and blocked `recv()`s; the
        // batcher is alive, so every in-flight batch completes. Give the
        // handlers a bounded window to observe the flag and finish.
        let deadline = Instant::now() + Duration::from_secs(5);
        while self.shared.conns.load(Ordering::SeqCst) > 0
            && Instant::now() < deadline
        {
            thread::sleep(Duration::from_millis(5));
        }
        // Dropping the master sender lets the batcher drain whatever is
        // still buffered and then exit on Disconnected.
        self.shared.queue.lock().unwrap().take();
        if let Some(t) = self.batch_thread.take() {
            t.join().ok();
        }
        Ok(())
    }

    /// `request_shutdown` + `wait` in one call.
    pub fn shutdown(&mut self) -> Result<()> {
        self.request_shutdown();
        self.wait()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.request_shutdown();
        let _ = self.wait();
    }
}

// ----- accept loop ------------------------------------------------------

fn accept_loop(shared: &Arc<Shared>, listener: TcpListener) {
    loop {
        if shared.cfg.install_signal_handlers {
            if signals::take_shutdown() {
                shared.shutdown.store(true, Ordering::SeqCst);
            }
            if signals::take_reload() {
                match shared.reload() {
                    Ok(sum) => eprintln!(
                        "serve: SIGHUP reload ok, model {}",
                        protocol::checksum_hex(sum)
                    ),
                    Err(e) => eprintln!(
                        "serve: SIGHUP reload failed ({e:#}); old model keeps serving"
                    ),
                }
            }
        }
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                shared.conns.fetch_add(1, Ordering::SeqCst);
                let conn_shared = shared.clone();
                let spawned = thread::Builder::new()
                    .name("serve-conn".to_string())
                    .spawn(move || {
                        let _guard = ConnGuard(&conn_shared.conns);
                        handle_connection(&conn_shared, stream);
                    });
                if spawned.is_err() {
                    shared.conns.fetch_sub(1, Ordering::SeqCst);
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(2));
            }
            Err(_) => thread::sleep(Duration::from_millis(2)),
        }
    }
}

/// Decrements the live-connection count however the handler exits.
struct ConnGuard<'a>(&'a AtomicUsize);

impl Drop for ConnGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

// ----- connection handler -----------------------------------------------

/// Read timeout used to keep handlers responsive to the shutdown flag.
const POLL_TIMEOUT: Duration = Duration::from_millis(100);
/// Overall deadline for one request's payload bytes to arrive.
const PAYLOAD_DEADLINE: Duration = Duration::from_secs(10);

fn handle_connection(shared: &Arc<Shared>, stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(POLL_TIMEOUT));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);

    // Handshake.
    let Some(hello) = read_line(shared, &mut reader) else {
        return;
    };
    match protocol::parse_hello(hello.trim_end()) {
        Ok(v) if v == PROTOCOL_VERSION => {}
        Ok(v) => {
            let _ = writer.write_all(
                protocol::err_line(
                    ErrCode::Proto,
                    &format!("unsupported protocol version {v} (want {PROTOCOL_VERSION})"),
                )
                .as_bytes(),
            );
            return;
        }
        Err(e) => {
            let _ = writer
                .write_all(protocol::err_line(ErrCode::Proto, &format!("{e:#}")).as_bytes());
            return;
        }
    }
    {
        let m = shared.current_model();
        let greet = format!(
            "OK covermeans-serve {PROTOCOL_VERSION} model {} k {} dim {}\n",
            protocol::checksum_hex(m.checksum()),
            m.k(),
            m.dim()
        );
        if writer.write_all(greet.as_bytes()).is_err() {
            return;
        }
    }

    // Request loop.
    while let Some(line) = read_line(shared, &mut reader) {
        let line = line.trim_end();
        if line.is_empty() {
            continue;
        }
        let reply_done = if line.starts_with('{') {
            match protocol::parse_json_request(line) {
                Ok(req) => serve_predict(shared, &mut writer, req, Framing::Json),
                Err(e) => write_err(&mut writer, ErrCode::BadReq, &format!("{e:#}")),
            }
        } else if line.starts_with("BIN") {
            match read_bin_request(shared, &mut reader, line) {
                Ok(Some(req)) => {
                    serve_predict(shared, &mut writer, req, Framing::Bin)
                }
                Ok(None) => return, // shutdown or EOF mid-payload
                Err(e) => write_err(&mut writer, ErrCode::BadReq, &format!("{e:#}")),
            }
        } else {
            match line {
                "PING" => {
                    let sum = shared.current_model().checksum();
                    writer
                        .write_all(
                            format!("PONG {}\n", protocol::checksum_hex(sum)).as_bytes(),
                        )
                        .is_ok()
                }
                "STATS" => {
                    let mut snap = shared.stats.snapshot_json();
                    snap.push('\n');
                    writer.write_all(snap.as_bytes()).is_ok()
                }
                "RELOAD" => match shared.reload() {
                    Ok(sum) => writer
                        .write_all(
                            format!("RELOADED {}\n", protocol::checksum_hex(sum))
                                .as_bytes(),
                        )
                        .is_ok(),
                    Err(e) => {
                        write_err(&mut writer, ErrCode::Reload, &format!("{e:#}"))
                    }
                },
                "QUIT" => {
                    let _ = writer.write_all(b"BYE\n");
                    return;
                }
                "SHUTDOWN" => {
                    let _ = writer.write_all(b"BYE\n");
                    shared.shutdown.store(true, Ordering::SeqCst);
                    return;
                }
                other => write_err(
                    &mut writer,
                    ErrCode::BadReq,
                    &format!("unknown verb {other:?}"),
                ),
            }
        };
        if !reply_done {
            return;
        }
    }
}

/// Read one line, riding out read timeouts while the daemon is alive.
/// An idle connection may wait between requests indefinitely, but once a
/// line has started arriving the rest must land within
/// [`PAYLOAD_DEADLINE`] — a client stalled mid-request cannot pin this
/// handler thread (and with it the graceful-shutdown drain) forever.
/// Returns `None` on EOF, hard error, stall, or shutdown.
fn read_line(shared: &Shared, reader: &mut BufReader<TcpStream>) -> Option<String> {
    let mut buf = String::new();
    let mut started: Option<Instant> = None;
    loop {
        match reader.read_line(&mut buf) {
            Ok(0) => return None,
            Ok(_) => {
                if buf.ends_with('\n') {
                    return Some(buf);
                }
                // Partial line straddling a timeout boundary: keep going.
            }
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut =>
            {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return None;
                }
                // read_line appends whatever arrived before the timeout,
                // so a non-empty buffer means a request is in flight.
                if !buf.is_empty() {
                    let t0 = *started.get_or_insert_with(Instant::now);
                    if t0.elapsed() > PAYLOAD_DEADLINE {
                        return None;
                    }
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return None,
        }
    }
}

/// Read the raw-f64 payload that follows a `BIN` header. `Ok(None)`
/// means the connection died or the daemon is draining.
fn read_bin_request(
    shared: &Shared,
    reader: &mut BufReader<TcpStream>,
    header: &str,
) -> Result<Option<PredictRequest>> {
    let (n, dim) = protocol::parse_bin_header(header)?;
    let total = n
        .checked_mul(dim)
        .and_then(|c| c.checked_mul(8))
        .context("BIN payload size overflows")?;
    let mut payload = vec![0u8; total];
    let mut filled = 0usize;
    let deadline = Instant::now() + PAYLOAD_DEADLINE;
    while filled < total {
        match reader.read(&mut payload[filled..]) {
            Ok(0) => return Ok(None),
            Ok(got) => filled += got,
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut =>
            {
                if shared.shutdown.load(Ordering::SeqCst)
                    || Instant::now() > deadline
                {
                    return Ok(None);
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => return Ok(None),
        }
    }
    let rows: Vec<f64> = payload
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    Ok(Some(PredictRequest { rows, n, dim }))
}

enum Framing {
    Json,
    Bin,
}

/// Enqueue one predict job, wait for the batcher's scatter, and write the
/// reply in the request's framing. Returns `false` when the connection
/// should close.
fn serve_predict(
    shared: &Arc<Shared>,
    writer: &mut TcpStream,
    req: PredictRequest,
    framing: Framing,
) -> bool {
    debug_assert!(req.n <= MAX_REQUEST_ROWS);
    {
        let m = shared.current_model();
        if req.dim != m.dim() {
            return write_err(
                writer,
                ErrCode::BadDim,
                &format!("request dim {} but model dim {}", req.dim, m.dim()),
            );
        }
    }
    let tx = match shared.queue.lock().unwrap().as_ref() {
        Some(tx) => tx.clone(),
        None => {
            return write_err(writer, ErrCode::Retry, "daemon is shutting down")
        }
    };
    let (reply_tx, reply_rx) = mpsc::channel();
    let job = Job {
        rows: req.rows,
        n: req.n,
        dim: req.dim,
        reply: reply_tx,
    };
    match tx.try_send(job) {
        Ok(()) => {}
        Err(TrySendError::Full(_)) => {
            ServeStats::bump(&shared.stats.queue_full_rejects);
            return write_err(
                writer,
                ErrCode::Retry,
                &format!(
                    "batch queue full (depth {}), retry later",
                    shared.cfg.queue_depth
                ),
            );
        }
        Err(TrySendError::Disconnected(_)) => {
            return write_err(writer, ErrCode::Retry, "daemon is shutting down")
        }
    }
    ServeStats::bump(&shared.stats.requests);
    // The batcher either answers or drops the job's reply sender (its
    // loop never blocks forever), so this recv cannot deadlock.
    match reply_rx.recv() {
        Ok(BatchReply::Ok { labels, distances, checksum, mode }) => {
            let hex = protocol::checksum_hex(checksum);
            match framing {
                Framing::Json => {
                    let line =
                        protocol::json_reply(&labels, &distances, &hex, mode.name());
                    writer.write_all(line.as_bytes()).is_ok()
                }
                Framing::Bin => {
                    let mut out = Vec::with_capacity(
                        32 + labels.len() * 4 + distances.len() * 8,
                    );
                    out.extend_from_slice(
                        format!("BINOK {} {hex}\n", labels.len()).as_bytes(),
                    );
                    for l in &labels {
                        out.extend_from_slice(&l.to_le_bytes());
                    }
                    for d in &distances {
                        out.extend_from_slice(&d.to_le_bytes());
                    }
                    writer.write_all(&out).is_ok()
                }
            }
        }
        Ok(BatchReply::WrongDim { expected }) => write_err(
            writer,
            ErrCode::BadDim,
            &format!("model dim changed to {expected} during a hot-reload"),
        ),
        Err(_) => write_err(writer, ErrCode::Retry, "batch dropped during drain"),
    }
}

fn write_err(writer: &mut TcpStream, code: ErrCode, msg: &str) -> bool {
    writer
        .write_all(protocol::err_line(code, msg).as_bytes())
        .is_ok()
}

// ----- batcher ----------------------------------------------------------

/// Idle poll period: how often an empty batcher rechecks for exit.
const IDLE_POLL: Duration = Duration::from_millis(25);

fn batcher_loop(shared: &Arc<Shared>, rx: Receiver<Job>) {
    // One pool for the daemon lifetime: worker threads and their parked
    // condvars persist across batches (no per-request spawn cost).
    let par = Parallelism::new_opts(shared.cfg.threads, shared.cfg.pin_workers);
    loop {
        let first = match rx.recv_timeout(IDLE_POLL) {
            Ok(job) => job,
            Err(RecvTimeoutError::Timeout) => continue,
            // All senders gone: the master sender was dropped by the
            // drain and no handler holds a clone — nothing can arrive.
            Err(RecvTimeoutError::Disconnected) => return,
        };
        let mut jobs = vec![first];
        let mut total = jobs[0].n;
        let deadline =
            Instant::now() + Duration::from_micros(shared.cfg.batch_wait_us);
        while total < shared.cfg.max_batch {
            let now = Instant::now();
            let job = if now >= deadline {
                match rx.try_recv() {
                    Ok(j) => j,
                    Err(_) => break,
                }
            } else {
                match rx.recv_timeout(deadline - now) {
                    Ok(j) => j,
                    Err(_) => break,
                }
            };
            total += job.n;
            jobs.push(job);
        }
        run_batch(shared, &par, jobs);
    }
}

/// One coalesced pass: snapshot the model, predict all matching rows,
/// scatter per-job slices.
fn run_batch(shared: &Arc<Shared>, par: &Parallelism, jobs: Vec<Job>) {
    let model = shared.current_model();
    let dim = model.dim();
    let mut ok_jobs = Vec::with_capacity(jobs.len());
    let mut rows = Vec::new();
    for job in jobs {
        if job.dim != dim {
            // Raced a hot-reload that changed dimensionality.
            let _ = job.reply.send(BatchReply::WrongDim { expected: dim });
            continue;
        }
        rows.extend_from_slice(&job.rows);
        ok_jobs.push(job);
    }
    if ok_jobs.is_empty() {
        return;
    }
    let n: usize = ok_jobs.iter().map(|j| j.n).sum();
    let data = Matrix::from_vec(rows, n, dim);
    let pred =
        model.predict_opts_par(&data, &shared.cfg.predict_options(), par);
    ServeStats::bump(&shared.stats.batches);
    ServeStats::add(&shared.stats.rows, n as u64);
    ServeStats::add(&shared.stats.query_evals, pred.query_evals);
    ServeStats::add(&shared.stats.prep_evals, pred.prep_evals);
    ServeStats::add(&shared.stats.f32_fallbacks, pred.f32_fallbacks);
    let checksum = model.checksum();
    let mut at = 0usize;
    for job in ok_jobs {
        let labels = pred.labels[at..at + job.n].to_vec();
        let distances = pred.distances[at..at + job.n].to_vec();
        at += job.n;
        // A handler that gave up (dead connection) just drops its
        // receiver; that is not the batcher's problem.
        let _ = job.reply.send(BatchReply::Ok {
            labels,
            distances,
            checksum,
            mode: pred.mode,
        });
    }
}

// ----- signals ----------------------------------------------------------

/// SIGHUP → reload, SIGINT/SIGTERM → shutdown, via the crate-global
/// atomic flags the accept loop polls (shared with `covermeans run`'s
/// checkpoint-then-exit path).
use crate::signals;
