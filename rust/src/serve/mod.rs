//! The resident serving daemon: `covermeans serve --model FILE.kmm
//! --addr HOST:PORT`.
//!
//! PR 5 made a trained model persistable; this subsystem makes it
//! *resident*. A [`server::Server`] loads the `.kmm` once, pre-builds
//! the serving indexes (the cover tree over centers, or the
//! inter-center bound matrix — whichever the configured
//! [`crate::kmeans::PredictMode`] resolves to), keeps one persistent
//! [`crate::parallel::Parallelism`] worker pool warm for its whole
//! lifetime, and answers predict requests over TCP.
//!
//! Three properties define the design:
//!
//! - **Coalescing.** Connection handlers feed a *bounded* MPSC queue; a
//!   single batcher thread drains up to `max_batch` rows (or waits
//!   `batch_wait_us` after the first job), runs **one**
//!   `predict_par` pass over the warm pool, and scatters per-connection
//!   label/distance slices. Many tiny requests amortize into one
//!   tree/scan pass.
//! - **Backpressure.** The queue bound is the memory bound: when the
//!   batcher falls behind, new requests get `ERR RETRY` (a retryable
//!   code, counted in `queue_full_rejects`) instead of growing an
//!   unbounded buffer.
//! - **Atomic hot-reload.** `RELOAD` (or SIGHUP) re-reads the model
//!   file and swaps an `Arc` pointer only after the bytes parse and the
//!   stored checksum verifies. A corrupt or truncated file can never
//!   change served output. Every reply carries the serving model's
//!   checksum as a version tag, so clients see exactly when the swap
//!   landed.
//!
//! Determinism carries over from the offline path: served labels are
//! byte-identical to `model.predict` on the same rows, for every
//! `PredictMode` and any thread count.
//!
//! Wire format lives in [`protocol`]; counters in [`stats`]; the test /
//! bench client in [`client`].

pub mod client;
pub mod protocol;
pub mod server;
pub mod stats;

pub use client::{remote_error, ServeClient};
pub use protocol::{
    checksum_hex, ErrCode, PredictReply, RemoteError, PROTOCOL_VERSION,
};
pub use server::{ServeConfig, Server};
pub use stats::{counter, ServeStats};
