//! A small synchronous client for the serve protocol, used by the e2e
//! tests and the serve bench section (and usable as a reference
//! implementation for other languages — the protocol is a handful of
//! newline-delimited verbs, see [`super::protocol`]).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::protocol::{
    self, PredictReply, RemoteError, MAX_REQUEST_ROWS, PROTOCOL_VERSION,
};
use crate::data::Matrix;

/// One connection to a running daemon, handshake already done.
pub struct ServeClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Version tag announced at the handshake (16 hex digits).
    model: String,
    k: usize,
    dim: usize,
}

impl ServeClient {
    /// Connect and handshake. Fails on version mismatch or a non-serve
    /// endpoint.
    pub fn connect(addr: &str) -> Result<ServeClient> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).ok();
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .context("set read timeout")?;
        let mut writer = stream.try_clone().context("clone stream")?;
        let mut reader = BufReader::new(stream);
        writer
            .write_all(format!("CMSERVE {PROTOCOL_VERSION}\n").as_bytes())
            .context("send hello")?;
        let mut greet = String::new();
        reader.read_line(&mut greet).context("read greeting")?;
        let greet = greet.trim_end();
        if let Some(err) = protocol::parse_err_line(greet) {
            bail!(err);
        }
        // OK covermeans-serve <ver> model <hex16> k <k> dim <dim>
        let toks: Vec<&str> = greet.split_ascii_whitespace().collect();
        let [ok, name, ver, m_kw, model, k_kw, k, d_kw, dim] = toks[..] else {
            bail!("bad greeting {greet:?}");
        };
        if ok != "OK"
            || name != "covermeans-serve"
            || m_kw != "model"
            || k_kw != "k"
            || d_kw != "dim"
        {
            bail!("bad greeting {greet:?}");
        }
        let ver: u32 = ver.parse().context("greeting version")?;
        if ver != PROTOCOL_VERSION {
            bail!("server speaks protocol {ver}, client wants {PROTOCOL_VERSION}");
        }
        Ok(ServeClient {
            reader,
            writer,
            model: model.to_string(),
            k: k.parse().context("greeting k")?,
            dim: dim.parse().context("greeting dim")?,
        })
    }

    /// Model version tag from the handshake (may be stale after a
    /// reload; predict replies carry the current one).
    pub fn model(&self) -> &str {
        &self.model
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    fn read_reply_line(&mut self) -> Result<String> {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).context("read reply")?;
        if n == 0 {
            bail!("server closed the connection");
        }
        Ok(line.trim_end().to_string())
    }

    /// Turn an `ERR` line into a typed [`RemoteError`] failure.
    fn check_err(line: &str) -> Result<()> {
        if let Some(err) = protocol::parse_err_line(line) {
            bail!(err);
        }
        Ok(())
    }

    /// Predict via the JSON framing.
    pub fn predict_json(&mut self, data: &Matrix) -> Result<PredictReply> {
        anyhow::ensure!(
            data.rows() > 0 && data.rows() <= MAX_REQUEST_ROWS,
            "request must carry 1..={MAX_REQUEST_ROWS} rows"
        );
        let line =
            protocol::json_request(data.as_slice(), data.rows(), data.cols());
        self.writer.write_all(line.as_bytes()).context("send request")?;
        let reply = self.read_reply_line()?;
        Self::check_err(&reply)?;
        let parsed = protocol::parse_json_reply(&reply)?;
        anyhow::ensure!(
            parsed.labels.len() == data.rows(),
            "server answered {} labels for {} rows",
            parsed.labels.len(),
            data.rows()
        );
        Ok(parsed)
    }

    /// Predict via the raw-f64 binary framing.
    pub fn predict_bin(&mut self, data: &Matrix) -> Result<PredictReply> {
        anyhow::ensure!(
            data.rows() > 0 && data.rows() <= MAX_REQUEST_ROWS,
            "request must carry 1..={MAX_REQUEST_ROWS} rows"
        );
        let (n, dim) = (data.rows(), data.cols());
        let mut frame = Vec::with_capacity(24 + n * dim * 8);
        frame.extend_from_slice(format!("BIN {n} {dim}\n").as_bytes());
        for v in data.as_slice() {
            frame.extend_from_slice(&v.to_le_bytes());
        }
        self.writer.write_all(&frame).context("send request")?;
        let header = self.read_reply_line()?;
        Self::check_err(&header)?;
        // BINOK <nrows> <hex16>
        let toks: Vec<&str> = header.split_ascii_whitespace().collect();
        let ["BINOK", rows, model] = toks[..] else {
            bail!("bad binary reply header {header:?}");
        };
        let rows: usize = rows.parse().context("BINOK rows")?;
        anyhow::ensure!(
            rows == n,
            "server answered {rows} labels for {n} rows"
        );
        let mut raw = vec![0u8; rows * 4 + rows * 8];
        self.reader.read_exact(&mut raw).context("read binary payload")?;
        let labels = raw[..rows * 4]
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let distances = raw[rows * 4..]
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(PredictReply {
            labels,
            distances,
            model: model.to_string(),
            mode: String::new(),
        })
    }

    /// `PING` → the current model version tag.
    pub fn ping(&mut self) -> Result<String> {
        self.writer.write_all(b"PING\n").context("send PING")?;
        let reply = self.read_reply_line()?;
        Self::check_err(&reply)?;
        reply
            .strip_prefix("PONG ")
            .map(str::to_string)
            .with_context(|| format!("bad PING reply {reply:?}"))
    }

    /// `STATS` → the one-line JSON counter snapshot.
    pub fn stats_json(&mut self) -> Result<String> {
        self.writer.write_all(b"STATS\n").context("send STATS")?;
        let reply = self.read_reply_line()?;
        Self::check_err(&reply)?;
        Ok(reply)
    }

    /// `RELOAD` → the new model version tag; fails with a
    /// [`RemoteError`] of code `RELOAD` (old model keeps serving) when
    /// the file on disk does not verify.
    pub fn reload(&mut self) -> Result<String> {
        self.writer.write_all(b"RELOAD\n").context("send RELOAD")?;
        let reply = self.read_reply_line()?;
        Self::check_err(&reply)?;
        reply
            .strip_prefix("RELOADED ")
            .map(str::to_string)
            .with_context(|| format!("bad RELOAD reply {reply:?}"))
    }

    /// Close this connection politely.
    pub fn quit(mut self) -> Result<()> {
        self.writer.write_all(b"QUIT\n").context("send QUIT")?;
        let _ = self.read_reply_line();
        Ok(())
    }

    /// Ask the daemon to shut down gracefully (drains in-flight batches).
    pub fn shutdown_server(mut self) -> Result<()> {
        self.writer.write_all(b"SHUTDOWN\n").context("send SHUTDOWN")?;
        let _ = self.read_reply_line();
        Ok(())
    }
}

/// Downcast helper: the [`RemoteError`] inside an `anyhow` failure, if
/// that is what it is.
pub fn remote_error(err: &anyhow::Error) -> Option<&RemoteError> {
    err.downcast_ref::<RemoteError>()
}
