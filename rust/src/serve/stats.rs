//! Per-daemon counters behind the `STATS` verb.
//!
//! Every counter is a relaxed [`AtomicU64`]: the numbers are an
//! observability surface (throughput claims, reject rates, reload
//! health), not a synchronization mechanism, so no ordering stronger
//! than `Relaxed` is needed.

use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic counters accumulated over the daemon lifetime.
#[derive(Debug, Default)]
pub struct ServeStats {
    /// Predict requests accepted into the batch queue.
    pub requests: AtomicU64,
    /// Data rows served (sum of request sizes that got an `OK` reply).
    pub rows: AtomicU64,
    /// Coalesced batches executed by the batcher.
    pub batches: AtomicU64,
    /// Predict requests rejected with `ERR RETRY` because the bounded
    /// queue was full (the backpressure path).
    pub queue_full_rejects: AtomicU64,
    /// Hot-reloads that parsed, verified, and swapped in a new model.
    pub reload_ok: AtomicU64,
    /// Hot-reload attempts that failed (old model kept serving).
    pub reload_fail: AtomicU64,
    /// Hot-reloads where the primary `.kmm` was rejected and the `.prev`
    /// generation retained by the atomic model writer was served instead
    /// (checkpoint-style generation fallback).
    pub reload_fallback: AtomicU64,
    /// Point-center distance evaluations spent answering queries.
    pub query_evals: AtomicU64,
    /// Distance evaluations spent building serving indexes (initial
    /// prewarm plus every successful reload).
    pub prep_evals: AtomicU64,
    /// f32-mode queries that failed the certified accept test and fell
    /// back to an exact f64 rescan (zero when serving in f64).
    pub f32_fallbacks: AtomicU64,
}

impl ServeStats {
    pub fn new() -> ServeStats {
        ServeStats::default()
    }

    pub fn add(field: &AtomicU64, n: u64) {
        field.fetch_add(n, Ordering::Relaxed);
    }

    pub fn bump(field: &AtomicU64) {
        field.fetch_add(1, Ordering::Relaxed);
    }

    fn get(field: &AtomicU64) -> u64 {
        field.load(Ordering::Relaxed)
    }

    /// One-line JSON snapshot (the `STATS` reply body). Besides the
    /// counters it carries one static provenance field: `kernel`, the
    /// distance-kernel dispatch this process selected at startup
    /// (`"scalar"`, `"avx"`, or `"neon"` — see [`crate::kernels`]).
    pub fn snapshot_json(&self) -> String {
        format!(
            concat!(
                "{{\"requests\":{},\"rows\":{},\"batches\":{},",
                "\"queue_full_rejects\":{},\"reload_ok\":{},",
                "\"reload_fail\":{},\"reload_fallback\":{},",
                "\"query_evals\":{},\"prep_evals\":{},",
                "\"f32_fallbacks\":{},\"kernel\":\"{}\"}}"
            ),
            Self::get(&self.requests),
            Self::get(&self.rows),
            Self::get(&self.batches),
            Self::get(&self.queue_full_rejects),
            Self::get(&self.reload_ok),
            Self::get(&self.reload_fail),
            Self::get(&self.reload_fallback),
            Self::get(&self.query_evals),
            Self::get(&self.prep_evals),
            Self::get(&self.f32_fallbacks),
            crate::kernels::active_name(),
        )
    }
}

/// Pull one `"key":value` counter out of a [`ServeStats::snapshot_json`]
/// line — enough JSON for tests and the CLI's final stats print.
pub fn counter(snapshot: &str, key: &str) -> Option<u64> {
    let pat = format!("\"{key}\":");
    let at = snapshot.find(&pat)? + pat.len();
    let rest = &snapshot[at..];
    let end = rest
        .find(|c: char| !c.is_ascii_digit())
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_carries_every_counter() {
        let s = ServeStats::new();
        ServeStats::add(&s.requests, 7);
        ServeStats::add(&s.rows, 700);
        ServeStats::bump(&s.batches);
        ServeStats::bump(&s.queue_full_rejects);
        ServeStats::add(&s.reload_ok, 2);
        ServeStats::add(&s.reload_fail, 3);
        ServeStats::add(&s.reload_fallback, 4);
        ServeStats::add(&s.query_evals, 41);
        ServeStats::add(&s.prep_evals, 13);
        ServeStats::add(&s.f32_fallbacks, 5);
        let snap = s.snapshot_json();
        assert_eq!(counter(&snap, "requests"), Some(7));
        assert_eq!(counter(&snap, "rows"), Some(700));
        assert_eq!(counter(&snap, "batches"), Some(1));
        assert_eq!(counter(&snap, "queue_full_rejects"), Some(1));
        assert_eq!(counter(&snap, "reload_ok"), Some(2));
        assert_eq!(counter(&snap, "reload_fail"), Some(3));
        assert_eq!(counter(&snap, "reload_fallback"), Some(4));
        assert_eq!(counter(&snap, "query_evals"), Some(41));
        assert_eq!(counter(&snap, "prep_evals"), Some(13));
        assert_eq!(counter(&snap, "f32_fallbacks"), Some(5));
        let kernel_pat = format!("\"kernel\":\"{}\"", crate::kernels::active_name());
        assert!(snap.contains(&kernel_pat), "missing kernel field in {snap}");
        assert_eq!(counter(&snap, "nope"), None);
    }
}
