//! Wire protocol of the serving daemon: newline-delimited ASCII headers
//! with length-prefixed binary payloads, versioned at the handshake.
//!
//! The offline crate set has no serde, so the JSON framing is a
//! hand-rolled codec for the two fixed shapes the protocol uses (a
//! predict request's `rows` matrix, a predict reply's `labels` /
//! `distances` arrays). Floats are formatted with Rust's
//! shortest-round-trip `Display`, so a client parsing a JSON reply
//! recovers the served distances bit for bit.
//!
//! # Framing
//!
//! Every connection opens with a version handshake:
//!
//! ```text
//! C: CMSERVE 1\n
//! S: OK covermeans-serve 1 model <hex16> k <k> dim <dim>\n
//! ```
//!
//! then carries any number of requests, each answered in order:
//!
//! ```text
//! {"rows":[[x,...],...]}\n        JSON predict
//! BIN <nrows> <dim>\n<payload>    binary predict; payload is nrows*dim
//!                                 little-endian f64 (8 bytes each)
//! PING\n                          liveness + current model version
//! STATS\n                         one-line JSON counter snapshot
//! RELOAD\n                        re-parse the model file, swap on valid
//! QUIT\n                          close this connection
//! SHUTDOWN\n                      graceful daemon shutdown (drains)
//! ```
//!
//! Replies:
//!
//! ```text
//! {"ok":true,"model":"<hex16>","mode":"<tree|scan>",
//!  "labels":[...],"distances":[...]}\n
//! BINOK <nrows> <hex16>\n<nrows u32 LE labels><nrows f64 LE distances>
//! PONG <hex16>\n
//! RELOADED <hex16>\n
//! BYE\n
//! ERR <CODE> <message>\n
//! ```
//!
//! `<hex16>` is the serving model's `.kmm` checksum
//! ([`crate::kmeans::KMeansModel::checksum`]) — the model **version tag**
//! every data-bearing reply carries, so a client can detect a hot-reload
//! between two of its requests. Error codes: `RETRY` (transient — queue
//! full or daemon draining; resend later), `BADREQ` (malformed request),
//! `BADDIM` (row dimensionality does not match the serving model),
//! `RELOAD` (reload attempt failed; the old model keeps serving), `PROTO`
//! (handshake/version mismatch).

use anyhow::{bail, Context, Result};

/// Protocol version spoken by this build (the handshake's second token).
pub const PROTOCOL_VERSION: u32 = 1;

/// Hard cap on rows in a single request frame: bounds the allocation a
/// hostile or buggy header can demand before any payload arrives.
pub const MAX_REQUEST_ROWS: usize = 1 << 20;

/// Machine-readable error classes carried on `ERR` reply lines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrCode {
    /// Transient backpressure (bounded queue full, or daemon draining):
    /// the request was *not* served; resend after a short delay.
    Retry,
    /// Malformed request line or payload.
    BadReq,
    /// Row dimensionality does not match the serving model.
    BadDim,
    /// A `RELOAD` failed (unreadable/corrupt file); old model still serves.
    Reload,
    /// Handshake violation (bad hello, unsupported version).
    Proto,
}

impl ErrCode {
    pub fn name(&self) -> &'static str {
        match self {
            ErrCode::Retry => "RETRY",
            ErrCode::BadReq => "BADREQ",
            ErrCode::BadDim => "BADDIM",
            ErrCode::Reload => "RELOAD",
            ErrCode::Proto => "PROTO",
        }
    }

    pub fn parse(s: &str) -> Option<ErrCode> {
        match s {
            "RETRY" => Some(ErrCode::Retry),
            "BADREQ" => Some(ErrCode::BadReq),
            "BADDIM" => Some(ErrCode::BadDim),
            "RELOAD" => Some(ErrCode::Reload),
            "PROTO" => Some(ErrCode::Proto),
            _ => None,
        }
    }
}

/// An `ERR <CODE> <message>` reply surfaced client-side as a typed error
/// (wrap in `anyhow`; downcast to inspect the code).
#[derive(Debug, Clone)]
pub struct RemoteError {
    pub code: ErrCode,
    pub message: String,
}

impl std::fmt::Display for RemoteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "server replied ERR {}: {}", self.code.name(), self.message)
    }
}

impl std::error::Error for RemoteError {}

impl RemoteError {
    /// Is this the backpressure/drain class the client should retry?
    pub fn is_retryable(&self) -> bool {
        self.code == ErrCode::Retry
    }
}

/// The model version tag as it appears on the wire (16 lowercase hex
/// digits of the `.kmm` checksum).
pub fn checksum_hex(sum: u64) -> String {
    format!("{sum:016x}")
}

/// Format an `ERR` line; the message is flattened to one line so a framing
/// cannot be broken by a multi-line error chain.
pub fn err_line(code: ErrCode, message: &str) -> String {
    let mut flat = message.replace(['\n', '\r'], " ");
    const MAX: usize = 300;
    if flat.len() > MAX {
        let mut cut = MAX;
        while !flat.is_char_boundary(cut) {
            cut -= 1;
        }
        flat.truncate(cut);
        flat.push_str("...");
    }
    format!("ERR {} {flat}\n", code.name())
}

/// Parse the client hello (`CMSERVE <version>`); returns the version.
pub fn parse_hello(line: &str) -> Result<u32> {
    let mut it = line.split_ascii_whitespace();
    match (it.next(), it.next(), it.next()) {
        (Some("CMSERVE"), Some(v), None) => {
            v.parse().context("hello version is not a number")
        }
        _ => bail!("bad hello {line:?} (expected \"CMSERVE <version>\")"),
    }
}

/// One parsed predict request: `n` rows of `dim` coordinates, flattened
/// row-major.
#[derive(Debug, Clone, PartialEq)]
pub struct PredictRequest {
    pub rows: Vec<f64>,
    pub n: usize,
    pub dim: usize,
}

/// Parse the `BIN <nrows> <dim>` header (payload framing is the caller's
/// job — it knows the stream).
pub fn parse_bin_header(line: &str) -> Result<(usize, usize)> {
    let rest = line
        .strip_prefix("BIN")
        .context("not a BIN header")?
        .trim();
    let mut it = rest.split_ascii_whitespace();
    let (Some(n), Some(d), None) = (it.next(), it.next(), it.next()) else {
        bail!("bad BIN header {line:?} (expected \"BIN <nrows> <dim>\")");
    };
    let n: usize = n.parse().context("BIN nrows")?;
    let d: usize = d.parse().context("BIN dim")?;
    if n == 0 || d == 0 {
        bail!("BIN header rows and dim must be positive (got {n} x {d})");
    }
    if n > MAX_REQUEST_ROWS {
        bail!("BIN header rows {n} exceeds the per-request cap {MAX_REQUEST_ROWS}");
    }
    Ok((n, d))
}

// ----- minimal JSON codec ----------------------------------------------

/// Cursor over one JSON line. Only the constructs the protocol emits are
/// understood: objects with string keys, arrays, numbers, strings without
/// escapes, `true`/`false`.
struct Cur<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn new(s: &'a str) -> Cur<'a> {
        Cur { s: s.as_bytes(), i: 0 }
    }

    fn skip_ws(&mut self) {
        while self.i < self.s.len() && self.s[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.skip_ws();
        self.s.get(self.i).copied()
    }

    fn eat(&mut self, b: u8) -> Result<()> {
        self.skip_ws();
        if self.s.get(self.i) == Some(&b) {
            self.i += 1;
            Ok(())
        } else {
            bail!(
                "JSON: expected {:?} at byte {}, found {:?}",
                b as char,
                self.i,
                self.s.get(self.i).map(|&c| c as char)
            );
        }
    }

    /// `true` if the next non-space byte is `b` (consumed when matched).
    fn eat_opt(&mut self, b: u8) -> bool {
        if self.peek() == Some(b) {
            self.i += 1;
            true
        } else {
            false
        }
    }

    /// A string literal without escape handling (the protocol never emits
    /// escapes; a client sending them gets a clean error).
    fn string(&mut self) -> Result<&'a str> {
        self.eat(b'"')?;
        let start = self.i;
        while let Some(&c) = self.s.get(self.i) {
            if c == b'\\' {
                bail!("JSON: escape sequences are not supported");
            }
            if c == b'"' {
                let out = std::str::from_utf8(&self.s[start..self.i])
                    .context("JSON: string is not UTF-8")?;
                self.i += 1;
                return Ok(out);
            }
            self.i += 1;
        }
        bail!("JSON: unterminated string");
    }

    fn number(&mut self) -> Result<f64> {
        self.skip_ws();
        let start = self.i;
        while let Some(&c) = self.s.get(self.i) {
            if c.is_ascii_digit()
                || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            {
                self.i += 1;
            } else {
                break;
            }
        }
        if start == self.i {
            bail!("JSON: expected a number at byte {start}");
        }
        std::str::from_utf8(&self.s[start..self.i])
            .ok()
            .and_then(|t| t.parse().ok())
            .with_context(|| format!("JSON: bad number at byte {start}"))
    }

    /// `[x, y, ...]` of numbers, appended to `out`; returns the count.
    fn number_array(&mut self, out: &mut Vec<f64>) -> Result<usize> {
        self.eat(b'[')?;
        let mut count = 0usize;
        if self.eat_opt(b']') {
            return Ok(0);
        }
        loop {
            out.push(self.number()?);
            count += 1;
            if self.eat_opt(b']') {
                return Ok(count);
            }
            self.eat(b',')?;
        }
    }

    fn done(&mut self) -> Result<()> {
        self.skip_ws();
        if self.i != self.s.len() {
            bail!("JSON: trailing bytes after the document");
        }
        Ok(())
    }
}

/// Parse a JSON predict request: `{"rows":[[...],...]}`. Every row must
/// share one dimensionality; the total row count honors
/// [`MAX_REQUEST_ROWS`].
pub fn parse_json_request(line: &str) -> Result<PredictRequest> {
    let mut c = Cur::new(line);
    c.eat(b'{')?;
    let key = c.string()?;
    if key != "rows" {
        bail!("JSON request: expected the \"rows\" key, got {key:?}");
    }
    c.eat(b':')?;
    c.eat(b'[')?;
    let mut rows = Vec::new();
    let mut n = 0usize;
    let mut dim = 0usize;
    if !c.eat_opt(b']') {
        loop {
            let len = c.number_array(&mut rows)?;
            if n == 0 {
                dim = len;
            } else if len != dim {
                bail!(
                    "JSON request: row {n} has {len} coordinates, expected {dim}"
                );
            }
            n += 1;
            if n > MAX_REQUEST_ROWS {
                bail!(
                    "JSON request: more than {MAX_REQUEST_ROWS} rows in one frame"
                );
            }
            if c.eat_opt(b']') {
                break;
            }
            c.eat(b',')?;
        }
    }
    c.eat(b'}')?;
    c.done()?;
    if n == 0 || dim == 0 {
        bail!("JSON request: empty rows");
    }
    Ok(PredictRequest { rows, n, dim })
}

/// Serialize a predict request as the JSON framing (client side).
pub fn json_request(rows: &[f64], n: usize, dim: usize) -> String {
    assert_eq!(rows.len(), n * dim, "flattened rows/shape mismatch");
    let mut s = String::with_capacity(16 + rows.len() * 8);
    s.push_str("{\"rows\":[");
    for i in 0..n {
        if i > 0 {
            s.push(',');
        }
        s.push('[');
        for (j, v) in rows[i * dim..(i + 1) * dim].iter().enumerate() {
            if j > 0 {
                s.push(',');
            }
            s.push_str(&v.to_string());
        }
        s.push(']');
    }
    s.push_str("]}\n");
    s
}

/// One served predict result as the client sees it.
#[derive(Debug, Clone, PartialEq)]
pub struct PredictReply {
    pub labels: Vec<u32>,
    pub distances: Vec<f64>,
    /// The serving model's version tag (16 hex digits).
    pub model: String,
    /// The strategy that answered (`tree` / `scan`).
    pub mode: String,
}

/// Serialize a predict reply as the JSON framing (server side).
pub fn json_reply(
    labels: &[u32],
    distances: &[f64],
    model_hex: &str,
    mode: &str,
) -> String {
    let mut s = String::with_capacity(64 + labels.len() * 12);
    s.push_str("{\"ok\":true,\"model\":\"");
    s.push_str(model_hex);
    s.push_str("\",\"mode\":\"");
    s.push_str(mode);
    s.push_str("\",\"labels\":[");
    for (i, l) in labels.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&l.to_string());
    }
    s.push_str("],\"distances\":[");
    for (i, d) in distances.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&d.to_string());
    }
    s.push_str("]}\n");
    s
}

/// Parse a JSON predict reply (client side). Keys are read in the fixed
/// order [`json_reply`] writes them.
pub fn parse_json_reply(line: &str) -> Result<PredictReply> {
    let mut c = Cur::new(line);
    c.eat(b'{')?;
    let expect_key = |c: &mut Cur, want: &str| -> Result<()> {
        let k = c.string()?;
        if k != want {
            bail!("JSON reply: expected key {want:?}, got {k:?}");
        }
        c.eat(b':')
    };
    expect_key(&mut c, "ok")?;
    // `true` / `false` literal.
    let ok = if c.eat_opt(b't') {
        c.eat(b'r')?;
        c.eat(b'u')?;
        c.eat(b'e')?;
        true
    } else {
        bail!("JSON reply: ok is not true");
    };
    debug_assert!(ok);
    c.eat(b',')?;
    expect_key(&mut c, "model")?;
    let model = c.string()?.to_string();
    c.eat(b',')?;
    expect_key(&mut c, "mode")?;
    let mode = c.string()?.to_string();
    c.eat(b',')?;
    expect_key(&mut c, "labels")?;
    let mut raw = Vec::new();
    c.number_array(&mut raw)?;
    let labels = raw
        .iter()
        .map(|&v| {
            if v.fract() == 0.0 && (0.0..=u32::MAX as f64).contains(&v) {
                Ok(v as u32)
            } else {
                bail!("JSON reply: label {v} is not a u32")
            }
        })
        .collect::<Result<Vec<u32>>>()?;
    c.eat(b',')?;
    expect_key(&mut c, "distances")?;
    let mut distances = Vec::new();
    c.number_array(&mut distances)?;
    c.eat(b'}')?;
    c.done()?;
    if labels.len() != distances.len() {
        bail!(
            "JSON reply: {} labels but {} distances",
            labels.len(),
            distances.len()
        );
    }
    Ok(PredictReply { labels, distances, model, mode })
}

/// Split an `ERR <CODE> <message>` line into a [`RemoteError`]; `None` if
/// the line is not an error reply.
pub fn parse_err_line(line: &str) -> Option<RemoteError> {
    let rest = line.strip_prefix("ERR ")?;
    let (code, msg) = rest.split_once(' ').unwrap_or((rest, ""));
    Some(RemoteError {
        code: ErrCode::parse(code)?,
        message: msg.to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hello_roundtrip() {
        assert_eq!(parse_hello("CMSERVE 1").unwrap(), 1);
        assert_eq!(parse_hello("CMSERVE 7").unwrap(), 7);
        assert!(parse_hello("HTTP/1.1 GET /").is_err());
        assert!(parse_hello("CMSERVE").is_err());
        assert!(parse_hello("CMSERVE one").is_err());
    }

    #[test]
    fn json_request_roundtrip() {
        let rows = vec![1.5, -2.0, 3.25, 1e-3, 0.0, f64::MIN_POSITIVE];
        let line = json_request(&rows, 2, 3);
        let req = parse_json_request(line.trim_end()).unwrap();
        assert_eq!(req.n, 2);
        assert_eq!(req.dim, 3);
        for (a, b) in req.rows.iter().zip(&rows) {
            assert_eq!(a.to_bits(), b.to_bits(), "shortest-round-trip floats");
        }
    }

    #[test]
    fn json_request_rejects_malformed() {
        for bad in [
            "",
            "{}",
            "{\"rows\":[]}",
            "{\"rows\":[[]]}",
            "{\"rows\":[[1,2],[3]]}",
            "{\"points\":[[1]]}",
            "{\"rows\":[[1,2]]} trailing",
            "{\"rows\":[[1,\"x\"]]}",
        ] {
            assert!(parse_json_request(bad).is_err(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn json_reply_roundtrip() {
        let line = json_reply(
            &[3, 0, 4_000_000_000],
            &[0.5, 1.25e-7, 2.0],
            "00ff00ff00ff00ff",
            "tree",
        );
        let r = parse_json_reply(line.trim_end()).unwrap();
        assert_eq!(r.labels, vec![3, 0, 4_000_000_000]);
        assert_eq!(r.distances, vec![0.5, 1.25e-7, 2.0]);
        assert_eq!(r.model, "00ff00ff00ff00ff");
        assert_eq!(r.mode, "tree");
    }

    #[test]
    fn bin_header_bounds() {
        assert_eq!(parse_bin_header("BIN 4 8").unwrap(), (4, 8));
        assert!(parse_bin_header("BIN 0 8").is_err());
        assert!(parse_bin_header("BIN 4 0").is_err());
        assert!(parse_bin_header("BIN 4").is_err());
        assert!(parse_bin_header("BIN 4 8 junk").is_err());
        assert!(parse_bin_header(&format!("BIN {} 8", MAX_REQUEST_ROWS + 1)).is_err());
    }

    #[test]
    fn err_lines_roundtrip_and_stay_single_line() {
        let line = err_line(ErrCode::Retry, "queue full\nat depth 64");
        assert_eq!(line.matches('\n').count(), 1, "one trailing newline only");
        let e = parse_err_line(line.trim_end()).unwrap();
        assert_eq!(e.code, ErrCode::Retry);
        assert!(e.is_retryable());
        assert!(e.message.contains("queue full"));
        assert!(parse_err_line("BINOK 3 abc").is_none());
        for c in [
            ErrCode::Retry,
            ErrCode::BadReq,
            ErrCode::BadDim,
            ErrCode::Reload,
            ErrCode::Proto,
        ] {
            assert_eq!(ErrCode::parse(c.name()), Some(c));
        }
    }

    #[test]
    fn checksum_hex_is_16_digits() {
        assert_eq!(checksum_hex(0), "0000000000000000");
        assert_eq!(checksum_hex(u64::MAX), "ffffffffffffffff");
        assert_eq!(checksum_hex(0xdead_beef), "00000000deadbeef");
    }
}
