//! Spatial index substrates: the paper's cover tree (§2.3), the
//! k-d tree used by the Kanungo et al. baseline, and the per-iteration
//! center tree driving the dual-tree assignment pass.

pub mod centers;
pub mod covertree;
pub mod kdtree;
pub mod search;

pub use centers::{CenterNode, CenterTree, CenterTreeCache};
pub use covertree::{CoverTree, CoverTreeParams};
pub use kdtree::{KdTree, KdTreeParams};
pub use search::{knn, nearest, radius, Neighbor};
