//! Spatial index substrates: the paper's cover tree (§2.3) and the
//! k-d tree used by the Kanungo et al. baseline.

pub mod covertree;
pub mod kdtree;
pub mod search;

pub use covertree::{CoverTree, CoverTreeParams};
pub use kdtree::{KdTree, KdTreeParams};
pub use search::{knn, nearest, radius, Neighbor};
