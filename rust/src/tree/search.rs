//! Nearest-neighbor and radius search on the cover tree — the queries the
//! index was designed for (Beygelzimer et al. [2]; paper §2.3). Validates
//! the index substrate independently of k-means and provides the k-NN
//! utility a downstream user of the library expects.
//!
//! Both searches use the same ball bounds as Cover-means: a subtree rooted
//! at routing object `p` with radius `r` can contain a point within `t` of
//! the query `q` only if `d(q, p) <= t + r` (Eq. 6 rearranged).

use crate::data::matrix::Matrix;
use crate::metrics::DistCounter;
use crate::tree::covertree::{CoverTree, Node};

/// One search hit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Neighbor {
    pub index: u32,
    pub dist: f64,
}

/// Bounded max-heap of the current k best (simple Vec-based; k is small).
struct TopK {
    k: usize,
    items: Vec<Neighbor>,
}

impl TopK {
    fn new(k: usize) -> Self {
        TopK { k, items: Vec::with_capacity(k + 1) }
    }

    fn bound(&self) -> f64 {
        if self.items.len() < self.k {
            f64::INFINITY
        } else {
            self.items.last().unwrap().dist
        }
    }

    fn push(&mut self, n: Neighbor) {
        let pos = self
            .items
            .partition_point(|x| (x.dist, x.index) < (n.dist, n.index));
        self.items.insert(pos, n);
        if self.items.len() > self.k {
            self.items.pop();
        }
    }
}

/// Single nearest-neighbor query, specialized for the serving path
/// ([`crate::kmeans::KMeansModel::predict`] runs it against a cover tree
/// built *over the centers*, so `Neighbor::index` is directly the cluster
/// label). Semantically `knn(.., 1, ..)` without the `TopK` bookkeeping,
/// with one extra guarantee the batch-predict contract needs: ties on the
/// exact distance resolve to the **lowest point index**, matching a naive
/// index-order scan label for label (the tree visits candidates in an
/// order driven by the pruning bounds, so a plain `<` comparison would
/// keep whichever tied point happened to be seen first).
pub fn nearest(
    tree: &CoverTree,
    data: &Matrix,
    query: &[f64],
    dist: &mut DistCounter,
) -> Neighbor {
    let root = &tree.root;
    let d_root = dist.d(query, data.row(root.routing as usize));
    // The root routing object is a real dataset point: seed the bound with
    // its true distance instead of +inf so pruning starts immediately.
    let mut best = Neighbor { index: root.routing, dist: d_root };
    descend_nearest(data, query, root, d_root, &mut best, dist);
    best
}

/// Lowest-index tie-breaking: strictly closer always wins; an exact
/// distance tie wins only with a smaller index.
#[inline]
fn improves(dd: f64, idx: u32, best: &Neighbor) -> bool {
    dd < best.dist || (dd == best.dist && idx < best.index)
}

fn descend_nearest(
    data: &Matrix,
    query: &[f64],
    node: &Node,
    d_p: f64,
    best: &mut Neighbor,
    dist: &mut DistCounter,
) {
    // All prunes below use *strict* inequalities: a candidate whose lower
    // bound equals the current best distance may still tie it with a
    // lower index, so it must stay reachable.
    for &(idx, pd) in &node.singletons {
        if (d_p - pd).abs() > best.dist {
            continue;
        }
        let dd = if idx == node.routing {
            d_p
        } else {
            dist.d(query, data.row(idx as usize))
        };
        if improves(dd, idx, best) {
            *best = Neighbor { index: idx, dist: dd };
        }
    }
    let mut order: Vec<(f64, usize, f64)> = Vec::with_capacity(node.children.len());
    for (ci, ch) in node.children.iter().enumerate() {
        let d_c = if ch.routing == node.routing {
            d_p
        } else {
            // Parent-distance bound: d(q, c) >= |d(q,p) - d(p,c)|; when
            // even that exceeds best + radius the whole subtree (routing
            // object included) is strictly farther than the current best.
            if (d_p - ch.parent_dist).abs() > best.dist + ch.radius {
                continue;
            }
            dist.d(query, data.row(ch.routing as usize))
        };
        // The routing object is itself a candidate; folding it in here
        // (it also appears as a singleton deeper down) tightens the bound
        // before any descent.
        if improves(d_c, ch.routing, best) {
            *best = Neighbor { index: ch.routing, dist: d_c };
        }
        order.push(((d_c - ch.radius).max(0.0), ci, d_c));
    }
    order.sort_unstable_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    for (opt, ci, d_c) in order {
        if opt > best.dist {
            break; // sorted ascending: every later child is at least this far
        }
        descend_nearest(data, query, &node.children[ci], d_c, best, dist);
    }
}

/// k-nearest-neighbor query. Distance evaluations are counted into `dist`.
pub fn knn(
    tree: &CoverTree,
    data: &Matrix,
    query: &[f64],
    k: usize,
    dist: &mut DistCounter,
) -> Vec<Neighbor> {
    assert!(k >= 1);
    let mut top = TopK::new(k);
    let root = &tree.root;
    let d_root = dist.d(query, data.row(root.routing as usize));
    descend_knn(tree, data, query, root, d_root, &mut top, dist);
    top.items
}

/// Recursive descent; `d_p` is the (already computed) distance from the
/// query to this node's routing object.
fn descend_knn(
    tree: &CoverTree,
    data: &Matrix,
    query: &[f64],
    node: &Node,
    d_p: f64,
    top: &mut TopK,
    dist: &mut DistCounter,
) {
    // Singletons: reuse the stored parent distance as a lower bound
    // |d(q,p) - d(p,s)| <= d(q,s) to skip hopeless candidates.
    for &(idx, pd) in &node.singletons {
        if (d_p - pd).abs() > top.bound() {
            continue;
        }
        let dd = if idx == node.routing {
            d_p // already computed
        } else {
            dist.d(query, data.row(idx as usize))
        };
        if dd < top.bound() {
            top.push(Neighbor { index: idx, dist: dd });
        }
    }
    // Children ordered by optimistic bound (closest first expands the best
    // candidates early and tightens the pruning radius).
    let mut order: Vec<(f64, usize, f64)> = Vec::with_capacity(node.children.len());
    for (ci, ch) in node.children.iter().enumerate() {
        let d_c = if ch.routing == node.routing {
            d_p
        } else {
            // Prune without computing when even the parent-distance bound
            // cannot reach the subtree: d(q, c) >= |d(q,p) - d(p,c)|.
            if (d_p - ch.parent_dist).abs() > top.bound() + ch.radius {
                continue;
            }
            dist.d(query, data.row(ch.routing as usize))
        };
        order.push(((d_c - ch.radius).max(0.0), ci, d_c));
    }
    order.sort_unstable_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    for (opt, ci, d_c) in order {
        if opt > top.bound() {
            break; // all later children are at least this far
        }
        descend_knn(tree, data, query, &node.children[ci], d_c, top, dist);
    }
}

/// Radius query: all points within `radius` of `query` (inclusive),
/// sorted by distance.
pub fn radius(
    tree: &CoverTree,
    data: &Matrix,
    query: &[f64],
    radius: f64,
    dist: &mut DistCounter,
) -> Vec<Neighbor> {
    let mut out = Vec::new();
    let root = &tree.root;
    let d_root = dist.d(query, data.row(root.routing as usize));
    descend_radius(data, query, root, d_root, radius, &mut out, dist);
    out.sort_unstable_by(|a, b| (a.dist, a.index).partial_cmp(&(b.dist, b.index)).unwrap());
    out
}

fn descend_radius(
    data: &Matrix,
    query: &[f64],
    node: &Node,
    d_p: f64,
    t: f64,
    out: &mut Vec<Neighbor>,
    dist: &mut DistCounter,
) {
    if d_p > t + node.radius {
        return; // ball cannot intersect the query ball
    }
    for &(idx, pd) in &node.singletons {
        if (d_p - pd).abs() > t {
            continue;
        }
        let dd = if idx == node.routing {
            d_p
        } else {
            dist.d(query, data.row(idx as usize))
        };
        if dd <= t {
            out.push(Neighbor { index: idx, dist: dd });
        }
    }
    for ch in &node.children {
        let d_c = if ch.routing == node.routing {
            d_p
        } else {
            if (d_p - ch.parent_dist).abs() > t + ch.radius {
                continue;
            }
            dist.d(query, data.row(ch.routing as usize))
        };
        descend_radius(data, query, ch, d_c, t, out, dist);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::tree::covertree::CoverTreeParams;

    fn brute_knn(data: &Matrix, q: &[f64], k: usize) -> Vec<Neighbor> {
        let mut all: Vec<Neighbor> = (0..data.rows())
            .map(|i| Neighbor {
                index: i as u32,
                dist: crate::kernels::dist(q, data.row(i)),
            })
            .collect();
        all.sort_unstable_by(|a, b| {
            (a.dist, a.index).partial_cmp(&(b.dist, b.index)).unwrap()
        });
        all.truncate(k);
        all
    }

    #[test]
    fn knn_matches_brute_force() {
        let data = synth::istanbul(0.001, 50);
        let tree = CoverTree::build(
            &data,
            CoverTreeParams { scale_factor: 1.2, min_node_size: 20 },
        );
        for qi in [0usize, 7, 100] {
            let q: Vec<f64> = data.row(qi).to_vec();
            let mut dc = DistCounter::new();
            let got = knn(&tree, &data, &q, 5, &mut dc);
            let want = brute_knn(&data, &q, 5);
            let gd: Vec<f64> = got.iter().map(|n| n.dist).collect();
            let wd: Vec<f64> = want.iter().map(|n| n.dist).collect();
            for (a, b) in gd.iter().zip(&wd) {
                assert!((a - b).abs() < 1e-12, "{gd:?} vs {wd:?}");
            }
            // And it must have pruned: fewer distance computations than
            // brute force on clustered data.
            assert!(
                dc.count() < data.rows() as u64,
                "no pruning: {} >= {}",
                dc.count(),
                data.rows()
            );
        }
    }

    #[test]
    fn knn_off_sample_query() {
        let data = synth::gaussian_blobs(400, 3, 4, 0.5, 51);
        let tree = CoverTree::build(
            &data,
            CoverTreeParams { scale_factor: 1.3, min_node_size: 10 },
        );
        let q = vec![0.1, -0.2, 0.3];
        let mut dc = DistCounter::new();
        let got = knn(&tree, &data, &q, 3, &mut dc);
        let want = brute_knn(&data, &q, 3);
        for (a, b) in got.iter().zip(&want) {
            assert!((a.dist - b.dist).abs() < 1e-12);
        }
    }

    #[test]
    fn radius_matches_brute_force() {
        let data = synth::istanbul(0.0008, 52);
        let tree = CoverTree::build(&data, CoverTreeParams::default());
        let q: Vec<f64> = data.row(3).to_vec();
        let t = 0.05;
        let mut dc = DistCounter::new();
        let got = radius(&tree, &data, &q, t, &mut dc);
        let want: Vec<u32> = (0..data.rows())
            .filter(|&i| crate::kernels::dist(&q, data.row(i)) <= t)
            .map(|i| i as u32)
            .collect();
        let got_idx: Vec<u32> = {
            let mut v: Vec<u32> = got.iter().map(|n| n.index).collect();
            v.sort_unstable();
            v
        };
        let mut want_sorted = want.clone();
        want_sorted.sort_unstable();
        assert_eq!(got_idx, want_sorted);
        // Sorted by distance.
        for w in got.windows(2) {
            assert!(w[0].dist <= w[1].dist);
        }
    }

    #[test]
    fn nearest_matches_naive_scan_with_ties() {
        // Clustered data: the 1-NN specialization must agree with a naive
        // index-order scan on both the distance and the index (ties break
        // to the lowest index), and it must prune.
        let data = synth::istanbul(0.001, 54);
        let tree = CoverTree::build(
            &data,
            CoverTreeParams { scale_factor: 1.2, min_node_size: 8 },
        );
        for qi in [0usize, 5, 50, 200] {
            let q: Vec<f64> = data.row(qi).to_vec();
            let mut dc = DistCounter::new();
            let got = nearest(&tree, &data, &q, &mut dc);
            let want = brute_knn(&data, &q, 1)[0];
            assert_eq!(got.index, want.index, "query {qi}");
            assert_eq!(got.dist.to_bits(), want.dist.to_bits(), "query {qi}");
            assert!(dc.count() < data.rows() as u64, "no pruning for query {qi}");
        }
        // Off-sample queries too.
        for q in [vec![29.0, 41.0], vec![28.6, 41.3], vec![0.0, 0.0]] {
            let mut dc = DistCounter::new();
            let got = nearest(&tree, &data, &q, &mut dc);
            let want = brute_knn(&data, &q, 1)[0];
            assert_eq!(got.index, want.index);
            assert_eq!(got.dist.to_bits(), want.dist.to_bits());
        }
    }

    #[test]
    fn nearest_ties_break_to_lowest_index() {
        // Duplicated points force exact distance ties; the naive scan
        // convention (lowest index wins) must hold.
        let rows: Vec<Vec<f64>> = vec![
            vec![5.0, 5.0],
            vec![0.0, 0.0],
            vec![5.0, 5.0], // duplicate of row 0
            vec![9.0, 9.0],
            vec![0.0, 0.0], // duplicate of row 1
        ];
        let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
        let data = Matrix::from_rows(&refs);
        let tree = CoverTree::build(
            &data,
            CoverTreeParams { scale_factor: 1.2, min_node_size: 1 },
        );
        let mut dc = DistCounter::new();
        assert_eq!(nearest(&tree, &data, &[5.1, 5.1], &mut dc).index, 0);
        assert_eq!(nearest(&tree, &data, &[-0.1, 0.0], &mut dc).index, 1);
    }

    #[test]
    fn nearest_single_point_tree() {
        let data = Matrix::from_rows(&[&[1.0, 2.0]]);
        let tree = CoverTree::build(&data, CoverTreeParams::default());
        let mut dc = DistCounter::new();
        let nb = nearest(&tree, &data, &[1.0, 3.0], &mut dc);
        assert_eq!(nb.index, 0);
        assert!((nb.dist - 1.0).abs() < 1e-12);
    }

    #[test]
    fn knn_k_larger_than_n() {
        let data = synth::gaussian_blobs(10, 2, 2, 0.5, 53);
        let tree = CoverTree::build(
            &data,
            CoverTreeParams { scale_factor: 1.2, min_node_size: 2 },
        );
        let mut dc = DistCounter::new();
        let got = knn(&tree, &data, data.row(0), 20, &mut dc);
        assert_eq!(got.len(), 10);
    }
}
