//! Per-iteration cover tree over the k cluster centers, built entirely
//! from a distance *lookup* — in the dual-tree pass the lookup is the
//! inter-center matrix (`kmeans::bounds::InterCenter`), which every exact
//! iteration already computes, so (re)building this tree costs **zero
//! counted distance computations**.
//!
//! The structure mirrors the point tree ([`crate::tree::covertree`]):
//! `children[0]` is the self-child (same center, radius shrunk by the
//! scale factor) when children exist, every center appears in exactly one
//! singleton list across the tree, `radius` bounds the distance from the
//! node's routing center to every center in its subtree, and each child
//! and singleton stores its exact distance to the routing center — the
//! quantities the dual-tree node-pair prunes consume.
//!
//! Construction is sequential (k is tiny next to n and the build is pure
//! table lookups) and deterministic, so the dual-tree pass's candidate
//! entries — and therefore its task list and merge order — stay a
//! function of the data alone, as the `threads=N ≡ threads=1`
//! byte-identity contract requires.

use crate::tree::covertree::CoverTreeParams;

/// Splitting the center set below this size is not worth the pointer
/// chasing: a handful of centers scans faster flat than through children.
/// Much smaller than the point tree's default `min_node_size` (100) —
/// at k=256 a 100-minimum would leave the center tree a single leaf and
/// degenerate the dual pass to a per-node flat scan.
pub const CENTER_MIN_NODE: usize = 8;

/// A node of the center tree. Same shape as the point tree's node minus
/// the aggregates (centers are never assigned in bulk).
#[derive(Debug, Clone)]
pub struct CenterNode {
    /// Index of the routing center (a row of the current centers matrix).
    pub center: u32,
    /// Distance from this node's routing center to the parent's routing
    /// center (0 for the root and for self-children).
    pub parent_dist: f64,
    /// Cover radius: max distance from `center` to any center in the
    /// subtree. 0 for pure singleton leaves.
    pub radius: f64,
    /// Child nodes (empty for leaves); `children[0]` is the self-child.
    pub children: Vec<CenterNode>,
    /// Centers stored directly: `(center index, dist to routing center)`.
    /// The routing center itself appears exactly once among all singleton
    /// lists, at the node where its descent stops.
    pub singletons: Vec<(u32, f64)>,
}

impl CenterNode {
    /// Visit every center index in the subtree.
    pub fn for_each_center(&self, f: &mut impl FnMut(u32)) {
        for &(c, _) in &self.singletons {
            f(c);
        }
        for ch in &self.children {
            ch.for_each_center(f);
        }
    }

    /// Number of centers in the subtree.
    pub fn count(&self) -> usize {
        let mut n = 0usize;
        self.for_each_center(&mut |_| n += 1);
        n
    }
}

/// The per-iteration index over the centers.
#[derive(Debug, Clone)]
pub struct CenterTree {
    pub root: CenterNode,
    /// Number of centers indexed (k at build time).
    pub k: usize,
}

/// Build a cover tree over centers `0..k` using the distance lookup `d`
/// (symmetric, `d(i,i) == 0`). Mirrors the point tree's greedy
/// construction: root routed at center 0, near/far partition at
/// `radius / scale_factor`, self-child first, then farthest-point
/// promotion of the remaining far centers.
pub fn build_center_tree(
    k: usize,
    params: CoverTreeParams,
    d: &impl Fn(usize, usize) -> f64,
) -> CenterTree {
    assert!(params.scale_factor > 1.0, "scale factor must be > 1");
    assert!(k > 0, "empty center set");
    let elems: Vec<(u32, f64)> =
        (1..k as u32).map(|i| (i, d(0, i as usize))).collect();
    let root = build_node(&params, d, 0, 0.0, elems, true);
    CenterTree { root, k }
}

fn build_leaf(
    p: u32,
    parent_dist: f64,
    radius: f64,
    mut elems: Vec<(u32, f64)>,
    owns_routing: bool,
) -> CenterNode {
    let mut node = CenterNode {
        center: p,
        parent_dist,
        radius,
        children: Vec::new(),
        singletons: Vec::new(),
    };
    if owns_routing {
        node.singletons.push((p, 0.0));
    }
    node.singletons.append(&mut elems);
    node
}

fn build_node(
    params: &CoverTreeParams,
    d: &impl Fn(usize, usize) -> f64,
    p: u32,
    parent_dist: f64,
    elems: Vec<(u32, f64)>,
    owns_routing: bool,
) -> CenterNode {
    let radius = elems.iter().fold(0.0f64, |m, &(_, dd)| m.max(dd));
    if elems.len() < params.min_node_size || radius <= 0.0 {
        return build_leaf(p, parent_dist, radius, elems, owns_routing);
    }

    let cov = radius / params.scale_factor;
    let mut near: Vec<(u32, f64)> = Vec::new();
    let mut far: Vec<(u32, f64)> = Vec::new();
    for e in elems {
        if e.1 <= cov {
            near.push(e);
        } else {
            far.push(e);
        }
    }

    let mut node = CenterNode {
        center: p,
        parent_dist,
        radius,
        children: Vec::new(),
        singletons: Vec::new(),
    };
    // Self-child: same routing center, radius <= cov, dist-to-parent 0.
    let near_radius = near.iter().fold(0.0f64, |m, &(_, dd)| m.max(dd));
    node.children.push(build_node(params, d, p, 0.0, near, owns_routing));
    debug_assert!(node.children[0].radius <= near_radius + 1e-12);

    // Farthest-point promotion over the far set (no triangle shortcut —
    // lookups are free, unlike the point build's counted distances).
    while !far.is_empty() {
        let (far_idx, _) = far
            .iter()
            .enumerate()
            .max_by(|a, b| a.1 .1.partial_cmp(&b.1 .1).unwrap())
            .unwrap();
        let (q, q_pdist) = far.swap_remove(far_idx);
        let mut q_elems: Vec<(u32, f64)> = Vec::new();
        let mut rest: Vec<(u32, f64)> = Vec::with_capacity(far.len());
        for (idx, pd) in far {
            let dq = d(q as usize, idx as usize);
            if dq <= cov {
                q_elems.push((idx, dq));
            } else {
                rest.push((idx, pd));
            }
        }
        far = rest;
        node.children.push(build_node(params, d, q, q_pdist, q_elems, true));
    }
    node
}

/// Rebuild-or-reuse policy for the per-iteration center tree.
///
/// The tree indexes the *current* centers, so it is stale the moment any
/// center moves; the driver invalidates the cache after every update
/// whose movement vector is not identically zero. The reuse case is the
/// converged tail of a fit (all movements exactly 0.0) and warm-started
/// refits — there the k x k lookups and the tree are unchanged, so the
/// cached structure is bit-identical to a rebuild.
#[derive(Debug, Default)]
pub struct CenterTreeCache {
    tree: Option<CenterTree>,
}

impl CenterTreeCache {
    pub fn new() -> CenterTreeCache {
        CenterTreeCache { tree: None }
    }

    /// Drop the cached tree (a center moved; the index is stale).
    pub fn invalidate(&mut self) {
        self.tree = None;
    }

    /// Return the cached tree if it indexes `k` centers, else rebuild
    /// from the lookup.
    pub fn get_or_build(
        &mut self,
        k: usize,
        params: CoverTreeParams,
        d: &impl Fn(usize, usize) -> f64,
    ) -> &CenterTree {
        let stale = match &self.tree {
            Some(t) => t.k != k,
            None => true,
        };
        if stale {
            self.tree = Some(build_center_tree(k, params, d));
        }
        self.tree.as_ref().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dist;
    use crate::data::synth;

    fn exact_lookup(
        centers: &crate::data::Matrix,
    ) -> impl Fn(usize, usize) -> f64 + '_ {
        |i, j| dist(centers.row(i), centers.row(j))
    }

    fn check_invariants(
        centers: &crate::data::Matrix,
        node: &CenterNode,
    ) {
        let p = centers.row(node.center as usize);
        node.for_each_center(&mut |c| {
            let dd = dist(p, centers.row(c as usize));
            assert!(dd <= node.radius + 1e-9, "radius violated");
        });
        if let Some(first) = node.children.first() {
            assert_eq!(first.center, node.center, "self-child first");
            assert_eq!(first.parent_dist, 0.0);
        }
        for ch in &node.children {
            let dd = dist(p, centers.row(ch.center as usize));
            assert!((dd - ch.parent_dist).abs() < 1e-9, "child parent dist");
            assert!(ch.radius <= node.radius + 1e-9, "radius monotone");
            check_invariants(centers, ch);
        }
        for &(c, pd) in &node.singletons {
            let dd = dist(p, centers.row(c as usize));
            assert!((dd - pd).abs() < 1e-9, "singleton dist");
        }
    }

    #[test]
    fn builds_and_obeys_invariants() {
        for (k, seed) in [(3usize, 1u64), (17, 2), (64, 3), (256, 4)] {
            let centers = synth::gaussian_blobs(k, 5, 6, 1.0, seed);
            let params =
                CoverTreeParams { scale_factor: 1.3, min_node_size: CENTER_MIN_NODE };
            let tree = build_center_tree(k, params, &exact_lookup(&centers));
            assert_eq!(tree.k, k);
            assert_eq!(tree.root.count(), k, "every center indexed");
            let mut seen = vec![0u8; k];
            tree.root.for_each_center(&mut |c| seen[c as usize] += 1);
            assert!(seen.iter().all(|&c| c == 1), "each center exactly once");
            check_invariants(&centers, &tree.root);
        }
    }

    #[test]
    fn single_center_is_a_leaf() {
        let centers = synth::gaussian_blobs(1, 4, 1, 1.0, 9);
        let tree = build_center_tree(
            1,
            CoverTreeParams { scale_factor: 1.2, min_node_size: CENTER_MIN_NODE },
            &exact_lookup(&centers),
        );
        assert!(tree.root.children.is_empty());
        assert_eq!(tree.root.singletons, vec![(0, 0.0)]);
        assert_eq!(tree.root.radius, 0.0);
    }

    #[test]
    fn duplicate_centers_collapse() {
        // Coincident centers (an empty-cluster fit can produce them) must
        // land in a radius-0 leaf, not recurse forever.
        let rows: Vec<Vec<f64>> = vec![vec![1.0, 2.0]; 40];
        let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
        let centers = crate::data::Matrix::from_rows(&refs);
        let tree = build_center_tree(
            40,
            CoverTreeParams { scale_factor: 1.2, min_node_size: 4 },
            &exact_lookup(&centers),
        );
        assert!(tree.root.children.is_empty());
        assert_eq!(tree.root.radius, 0.0);
        assert_eq!(tree.root.count(), 40);
    }

    #[test]
    fn cache_rebuilds_on_invalidate_and_k_change() {
        let centers = synth::gaussian_blobs(20, 3, 4, 1.0, 5);
        let params =
            CoverTreeParams { scale_factor: 1.2, min_node_size: CENTER_MIN_NODE };
        let mut cache = CenterTreeCache::new();
        let r1 = cache.get_or_build(20, params, &exact_lookup(&centers)).root.center;
        // Reuse: same k, no invalidation.
        let r2 = cache.get_or_build(20, params, &exact_lookup(&centers)).root.center;
        assert_eq!(r1, r2);
        // k change forces a rebuild even without invalidation.
        let small = cache.get_or_build(7, params, &exact_lookup(&centers));
        assert_eq!(small.k, 7);
        cache.invalidate();
        let again = cache.get_or_build(20, params, &exact_lookup(&centers));
        assert_eq!(again.k, 20);
    }
}
