//! k-d tree with bounding boxes and node aggregates — the substrate of
//! Kanungo et al.'s filtering algorithm [8] that the paper compares against.
//!
//! Unlike the classic k-d tree, the filtering variant stores, per node, the
//! axis-aligned bounding box of its *cell* and the aggregate (vector sum,
//! count) of its points, so whole cells can be assigned to a center at
//! once. Splits use the midpoint rule along the longest box side (as in
//! Kanungo et al.), which can produce empty sides; empty sides are skipped.
//! This is the "two vectors per node" representation the paper contrasts
//! with the cover tree's one-vector ball representation (§1).

use crate::data::matrix::Matrix;

/// Node of the filtering k-d tree.
#[derive(Debug, Clone)]
pub struct KdNode {
    /// Bounding box of the points in this node (tight, not the cell).
    pub bbox_min: Vec<f64>,
    pub bbox_max: Vec<f64>,
    /// Aggregate sum of points and count.
    pub sum: Vec<f64>,
    pub weight: u32,
    /// Children; `None` for leaves.
    pub left: Option<Box<KdNode>>,
    pub right: Option<Box<KdNode>>,
    /// Point indices (only populated for leaves).
    pub points: Vec<u32>,
}

/// Construction parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KdTreeParams {
    /// Stop splitting at or below this many points (Kanungo uses 1; a
    /// larger leaf keeps the tree small like the cover tree's min size).
    pub leaf_size: usize,
    /// Maximum tree depth (guards degenerate midpoint splits).
    pub max_depth: usize,
}

impl Default for KdTreeParams {
    fn default() -> Self {
        KdTreeParams { leaf_size: 100, max_depth: 64 }
    }
}

/// The filtering k-d tree index.
#[derive(Debug, Clone)]
pub struct KdTree {
    pub root: KdNode,
    pub params: KdTreeParams,
    pub build_time: std::time::Duration,
    pub node_count: usize,
}

impl KdTree {
    pub fn build(data: &Matrix, params: KdTreeParams) -> KdTree {
        assert!(data.rows() > 0, "empty dataset");
        let sw = std::time::Instant::now();
        let idx: Vec<u32> = (0..data.rows() as u32).collect();
        let root = build_node(data, &params, idx, 0);
        let mut tree = KdTree {
            root,
            params,
            build_time: sw.elapsed(),
            node_count: 0,
        };
        tree.node_count = tree.root.count_nodes();
        tree
    }

    pub fn len(&self) -> usize {
        self.root.weight as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Approximate index memory in bytes: two box vectors + one sum vector
    /// per node (the paper's factor-of-two argument vs the cover tree).
    pub fn memory_bytes(&self, d: usize) -> usize {
        self.node_count * (std::mem::size_of::<KdNode>() + 3 * d * 8)
    }
}

fn build_node(data: &Matrix, params: &KdTreeParams, idx: Vec<u32>, depth: usize) -> KdNode {
    let d = data.cols();
    let mut bbox_min = vec![f64::INFINITY; d];
    let mut bbox_max = vec![f64::NEG_INFINITY; d];
    let mut sum = vec![0.0; d];
    for &i in &idx {
        let row = data.row(i as usize);
        for j in 0..d {
            bbox_min[j] = bbox_min[j].min(row[j]);
            bbox_max[j] = bbox_max[j].max(row[j]);
            sum[j] += row[j];
        }
    }
    let weight = idx.len() as u32;

    // Longest side and its extent.
    let (split_dim, extent) = (0..d)
        .map(|j| (j, bbox_max[j] - bbox_min[j]))
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap();

    if idx.len() <= params.leaf_size || depth >= params.max_depth || extent <= 0.0 {
        return KdNode {
            bbox_min,
            bbox_max,
            sum,
            weight,
            left: None,
            right: None,
            points: idx,
        };
    }

    // Midpoint rule along the longest side.
    let mid = 0.5 * (bbox_min[split_dim] + bbox_max[split_dim]);
    let (mut li, mut ri) = (Vec::new(), Vec::new());
    for &i in &idx {
        if data.get(i as usize, split_dim) <= mid {
            li.push(i);
        } else {
            ri.push(i);
        }
    }
    // Degenerate split (all points on one side of the midpoint despite a
    // positive extent cannot happen: the max point is > mid, the min point
    // is <= mid). Both sides are non-empty here.
    debug_assert!(!li.is_empty() && !ri.is_empty());

    KdNode {
        bbox_min,
        bbox_max,
        sum,
        weight,
        left: Some(Box::new(build_node(data, params, li, depth + 1))),
        right: Some(Box::new(build_node(data, params, ri, depth + 1))),
        points: Vec::new(),
    }
}

impl KdNode {
    pub fn is_leaf(&self) -> bool {
        self.left.is_none()
    }

    pub fn count_nodes(&self) -> usize {
        1 + self.left.as_ref().map_or(0, |n| n.count_nodes())
            + self.right.as_ref().map_or(0, |n| n.count_nodes())
    }

    pub fn depth(&self) -> usize {
        1 + self
            .left
            .as_ref()
            .map_or(0, |n| n.depth())
            .max(self.right.as_ref().map_or(0, |n| n.depth()))
    }

    /// Box midpoint (used by the filtering algorithm to pick the candidate
    /// the others are compared against).
    pub fn midpoint(&self) -> Vec<f64> {
        self.bbox_min
            .iter()
            .zip(&self.bbox_max)
            .map(|(&lo, &hi)| 0.5 * (lo + hi))
            .collect()
    }

    /// Visit all point indices in the subtree.
    pub fn for_each_point(&self, f: &mut impl FnMut(u32)) {
        for &i in &self.points {
            f(i);
        }
        if let Some(l) = &self.left {
            l.for_each_point(f);
        }
        if let Some(r) = &self.right {
            r.for_each_point(f);
        }
    }
}

/// The dominance test of Kanungo et al.: is candidate `z` "farther" from
/// the whole box than `z_star`, i.e. is every point of the box at least as
/// close to `z_star` as to `z`? Decided by checking the box corner that
/// maximally favors `z` (the vertex of the box extremal in the direction
/// `z - z_star`). Returns true if `z` can be pruned.
///
/// Costs two squared-distance evaluations to a synthesized corner point;
/// callers must account for them (see `kmeans::kanungo`).
pub fn is_farther(z: &[f64], z_star: &[f64], bbox_min: &[f64], bbox_max: &[f64]) -> bool {
    let mut dz = 0.0;
    let mut dstar = 0.0;
    for j in 0..z.len() {
        let corner = if z[j] > z_star[j] { bbox_max[j] } else { bbox_min[j] };
        let a = z[j] - corner;
        let b = z_star[j] - corner;
        dz += a * a;
        dstar += b * b;
    }
    dz >= dstar
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn check_invariants(data: &Matrix, node: &KdNode) {
        let d = data.cols();
        let mut count = 0u32;
        let mut sum = vec![0.0; d];
        node.for_each_point(&mut |i| {
            let row = data.row(i as usize);
            for j in 0..d {
                assert!(row[j] >= node.bbox_min[j] - 1e-12);
                assert!(row[j] <= node.bbox_max[j] + 1e-12);
                sum[j] += row[j];
            }
            count += 1;
        });
        assert_eq!(count, node.weight);
        for j in 0..d {
            assert!((sum[j] - node.sum[j]).abs() < 1e-6 * (1.0 + sum[j].abs()));
        }
        match (&node.left, &node.right) {
            (Some(l), Some(r)) => {
                assert_eq!(l.weight + r.weight, node.weight);
                check_invariants(data, l);
                check_invariants(data, r);
            }
            (None, None) => assert_eq!(node.points.len(), node.weight as usize),
            _ => panic!("half-split node"),
        }
    }

    #[test]
    fn builds_and_obeys_invariants() {
        let data = synth::gaussian_blobs(800, 5, 4, 1.0, 1);
        let tree = KdTree::build(&data, KdTreeParams { leaf_size: 10, max_depth: 64 });
        assert_eq!(tree.len(), 800);
        check_invariants(&data, &tree.root);
    }

    #[test]
    fn every_point_once() {
        let data = synth::istanbul(0.001, 2);
        let tree = KdTree::build(&data, KdTreeParams::default());
        let mut seen = vec![0u32; data.rows()];
        tree.root.for_each_point(&mut |i| seen[i as usize] += 1);
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn duplicates_stop_splitting() {
        let rows: Vec<Vec<f64>> = vec![vec![3.0, 3.0]; 500];
        let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
        let data = Matrix::from_rows(&refs);
        let tree = KdTree::build(&data, KdTreeParams { leaf_size: 10, max_depth: 64 });
        assert!(tree.root.is_leaf(), "zero-extent box must not split");
    }

    #[test]
    fn dominance_test_basic() {
        // Box [0,1]^2; z* at origin-ish, z far right: z prunable.
        let bmin = [0.0, 0.0];
        let bmax = [1.0, 1.0];
        assert!(is_farther(&[5.0, 0.5], &[0.5, 0.5], &bmin, &bmax));
        // z inside the box is never prunable vs an outside z*.
        assert!(!is_farther(&[0.5, 0.5], &[5.0, 0.5], &bmin, &bmax));
    }

    #[test]
    fn dominance_test_symmetry_break() {
        // Two candidates straddling the box: neither dominates.
        let bmin = [0.0];
        let bmax = [10.0];
        assert!(!is_farther(&[-1.0], &[11.0], &bmin, &bmax));
        assert!(!is_farther(&[11.0], &[-1.0], &bmin, &bmax));
    }

    #[test]
    fn deeper_than_cover_tree_on_same_data() {
        // The paper argues the binary k-d tree is deeper than the wide
        // cover tree; sanity-check on clustered 2-d data.
        let data = synth::istanbul(0.002, 5);
        let kd = KdTree::build(&data, KdTreeParams { leaf_size: 100, max_depth: 64 });
        let ct = crate::tree::covertree::CoverTree::build(
            &data,
            crate::tree::covertree::CoverTreeParams::default(),
        );
        assert!(kd.root.depth() >= ct.root.depth() / 2);
    }
}
