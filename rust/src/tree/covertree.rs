//! Cover tree with node aggregates — the paper's index (§2.3).
//!
//! A practical (simplified) cover tree built greedily in the spirit of
//! Beygelzimer et al. [2], with the paper's extensions:
//!
//! * **scaling factor** `b` (default 1.2, paper §2.3): each child cover
//!   radius is the parent's divided by `b`, trading fan-out vs depth;
//! * **minimum node size** (default 100, paper §4): construction stops
//!   splitting below this size and stores remaining points as *singletons*
//!   (radius-0 children kept compactly as `(index, parent_dist)` pairs);
//! * **aggregates**: each node stores the vector sum `S_x` and count `w_x`
//!   of every point in its subtree (paper §2.3), enabling whole-subtree
//!   cluster reassignment in O(d);
//! * **parent distances**: each child stores `d(p_parent, p_child)`, and
//!   each singleton stores its distance to the node's routing object —
//!   exactly the quantities Eqs. 7-8 and 12-14 consume. The routing object
//!   is its own first child ("self child") at distance 0, so distances to
//!   it are reusable down the tree (paper §2.3).
//!
//! Construction distance computations are counted into a separate counter
//! (the paper excludes build cost from Fig. 1 but includes it in Tables
//! 3-4; we report both).

use crate::data::matrix::Matrix;
use crate::metrics::DistCounter;
use crate::parallel::Parallelism;

/// A cover tree node. `children[0]` is always the self-child (same routing
/// object, smaller radius) when children exist.
#[derive(Debug, Clone)]
pub struct Node {
    /// Index of the routing object in the dataset.
    pub routing: u32,
    /// Distance from this node's routing object to the parent's routing
    /// object (0 for the root and for self-children).
    pub parent_dist: f64,
    /// Cover radius: max distance from `routing` to any point in the
    /// subtree (the `r_x` of Eq. 6). 0 for pure singleton leaves.
    pub radius: f64,
    /// Vector sum over all points in the subtree (`S_x`).
    pub sum: Vec<f64>,
    /// Number of points in the subtree (`w_x`).
    pub weight: u32,
    /// Child nodes (empty for leaves).
    pub children: Vec<Node>,
    /// Singleton points stored directly: `(point index, dist to routing)`.
    /// The routing object itself appears here **only at the node where its
    /// descent stops** (so each dataset point occurs exactly once among all
    /// singleton lists).
    pub singletons: Vec<(u32, f64)>,
}

/// Construction parameters (paper §4 defaults).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CoverTreeParams {
    /// Radius scaling factor between levels (`b`), > 1.
    pub scale_factor: f64,
    /// Stop splitting nodes with at most this many points.
    pub min_node_size: usize,
}

impl Default for CoverTreeParams {
    fn default() -> Self {
        CoverTreeParams { scale_factor: 1.2, min_node_size: 100 }
    }
}

/// The index: a root node over all points plus build-cost accounting.
#[derive(Debug, Clone)]
pub struct CoverTree {
    pub root: Node,
    pub params: CoverTreeParams,
    /// Distance computations spent in construction.
    pub build_distances: u64,
    /// Wall time of construction.
    pub build_time: std::time::Duration,
    /// Number of internal nodes (diagnostics / memory accounting).
    pub node_count: usize,
    /// Number of singleton entries (should equal N).
    pub singleton_count: usize,
}

impl CoverTree {
    /// Build over all rows of `data` (single-threaded).
    pub fn build(data: &Matrix, params: CoverTreeParams) -> CoverTree {
        CoverTree::build_with_threads(data, params, 1)
    }

    /// Build with up to `threads` workers (0 = all cores), spawning a
    /// fresh pool for the build. Callers with a long-lived pool (the
    /// workspace cache) should prefer
    /// [`CoverTree::build_with_parallelism`].
    pub fn build_with_threads(
        data: &Matrix,
        params: CoverTreeParams,
        threads: usize,
    ) -> CoverTree {
        CoverTree::build_with_parallelism(data, params, &Parallelism::new(threads))
    }

    /// Build over `par`'s (persistent) worker pool.
    ///
    /// Parallel construction expands the top of the tree sequentially into
    /// subtree tasks via a thread-count-independent policy and builds the
    /// tasks concurrently, merging their distance tallies in task order —
    /// so the resulting tree (structure, aggregates, and counted
    /// `build_distances`) is byte-identical to the sequential build at
    /// every thread count.
    pub fn build_with_parallelism(
        data: &Matrix,
        params: CoverTreeParams,
        par: &Parallelism,
    ) -> CoverTree {
        assert!(params.scale_factor > 1.0, "scale factor must be > 1");
        assert!(data.rows() > 0, "empty dataset");
        let sw = std::time::Instant::now();
        let mut dist = DistCounter::new();

        // Root routing object: first point (deterministic; the tree is an
        // index, any choice is valid).
        let root_pt = 0u32;
        let mut elems: Vec<(u32, f64)> = Vec::with_capacity(data.rows() - 1);
        for i in 1..data.rows() as u32 {
            let d = dist.d(data.row(root_pt as usize), data.row(i as usize));
            elems.push((i, d));
        }
        let root = if par.threads() > 1 && elems.len() >= PAR_MIN_SPLIT {
            build_root_parallel(data, &params, &mut dist, root_pt, elems, par)
        } else {
            build_node(data, &params, &mut dist, root_pt, 0.0, elems, true)
        };

        let mut tree = CoverTree {
            root,
            params,
            build_distances: dist.count(),
            build_time: sw.elapsed(),
            node_count: 0,
            singleton_count: 0,
        };
        let (nodes, singles) = tree.root.count_entries();
        tree.node_count = nodes;
        tree.singleton_count = singles;
        tree
    }

    /// Total number of points indexed.
    pub fn len(&self) -> usize {
        self.root.weight as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Approximate index memory in bytes (paper §1 argues the ball-per-node
    /// representation is ~2x smaller than k-d tree bounding boxes).
    pub fn memory_bytes(&self, d: usize) -> usize {
        self.node_count * (std::mem::size_of::<Node>() + d * 8)
            + self.singleton_count * 12
    }
}

/// Everything needed to build one (sub)tree node: the routing object, its
/// distance to the parent routing object, the covered elements
/// `(index, distance to routing)`, and whether this subtree emits the
/// routing object as a singleton.
struct ChildSpec {
    p: u32,
    parent_dist: f64,
    /// Max element distance (the node's cover radius), precomputed so the
    /// expansion policy can rank specs without rescanning.
    radius: f64,
    elems: Vec<(u32, f64)>,
    owns_routing: bool,
}

impl ChildSpec {
    /// Mirrors the leaf test in [`build_node`]: this spec would split.
    fn splits(&self, params: &CoverTreeParams) -> bool {
        self.elems.len() >= params.min_node_size && self.radius > 0.0
    }
}

/// Assemble a leaf node over `elems`.
fn build_leaf(
    data: &Matrix,
    p: u32,
    parent_dist: f64,
    radius: f64,
    mut elems: Vec<(u32, f64)>,
    owns_routing: bool,
) -> Node {
    let mut node = Node {
        routing: p,
        parent_dist,
        radius,
        sum: vec![0.0; data.cols()],
        weight: 0,
        children: Vec::new(),
        singletons: Vec::new(),
    };
    if owns_routing {
        node.singletons.push((p, 0.0));
    }
    node.singletons.append(&mut elems);
    finish_aggregates(data, &mut node);
    node
}

/// Partition a splitting node's elements into child specs: the self-child
/// (points within `cov` of `p`) first, then promoted routing objects in
/// promotion order (farthest-point heuristic). All counted distance
/// computations of the node body happen here, in a fixed order.
fn partition_children(
    data: &Matrix,
    params: &CoverTreeParams,
    dist: &mut DistCounter,
    p: u32,
    radius: f64,
    elems: Vec<(u32, f64)>,
    owns_routing: bool,
) -> Vec<ChildSpec> {
    // Children cover radius: shrink by the scaling factor.
    let cov = radius / params.scale_factor;

    // Partition: points within `cov` of p stay with the self-child.
    let mut near: Vec<(u32, f64)> = Vec::new();
    let mut far: Vec<(u32, f64)> = Vec::new();
    for e in elems {
        if e.1 <= cov {
            near.push(e);
        } else {
            far.push(e);
        }
    }

    let mut specs = Vec::new();
    // Self-child: same routing object, radius <= cov, dist-to-parent 0.
    let near_radius = near.iter().fold(0.0f64, |m, &(_, dd)| m.max(dd));
    specs.push(ChildSpec {
        p,
        parent_dist: 0.0,
        radius: near_radius,
        elems: near,
        owns_routing,
    });

    // Remaining far points: repeatedly promote the farthest point to a new
    // routing object and give it everything within `cov` of it
    // (farthest-point heuristic approximates the separation invariant).
    while !far.is_empty() {
        let (far_idx, _) = far
            .iter()
            .enumerate()
            .max_by(|a, b| a.1 .1.partial_cmp(&b.1 .1).unwrap())
            .unwrap();
        let (q, q_pdist) = far.swap_remove(far_idx);

        let mut q_elems: Vec<(u32, f64)> = Vec::new();
        let mut rest: Vec<(u32, f64)> = Vec::with_capacity(far.len());
        for (idx, pd) in far {
            // Triangle shortcut: if |d(x,p) - d(q,p)| > cov the point
            // cannot be within cov of q; skip the distance computation.
            if (pd - q_pdist).abs() > cov {
                rest.push((idx, pd));
                continue;
            }
            let dq = dist.d(data.row(q as usize), data.row(idx as usize));
            if dq <= cov {
                q_elems.push((idx, dq));
            } else {
                rest.push((idx, pd));
            }
        }
        far = rest;
        let q_radius = q_elems.iter().fold(0.0f64, |m, &(_, dd)| m.max(dd));
        specs.push(ChildSpec {
            p: q,
            parent_dist: q_pdist,
            radius: q_radius,
            elems: q_elems,
            owns_routing: true,
        });
    }
    specs
}

/// Recursive greedy construction.
///
/// `elems` holds `(index, distance to p)` for every point this node must
/// cover (excluding `p` itself iff `owns_routing`; the routing object is
/// carried implicitly and emitted as a singleton exactly once, at the node
/// where recursion stops).
fn build_node(
    data: &Matrix,
    params: &CoverTreeParams,
    dist: &mut DistCounter,
    p: u32,
    parent_dist: f64,
    elems: Vec<(u32, f64)>,
    owns_routing: bool,
) -> Node {
    let radius = elems.iter().fold(0.0f64, |m, &(_, dd)| m.max(dd));

    // Leaf: small enough, or all points coincide with the routing object.
    if elems.len() < params.min_node_size || radius <= 0.0 {
        return build_leaf(data, p, parent_dist, radius, elems, owns_routing);
    }

    let specs = partition_children(data, params, dist, p, radius, elems, owns_routing);
    let mut node = Node {
        routing: p,
        parent_dist,
        radius,
        sum: vec![0.0; data.cols()],
        weight: 0,
        children: Vec::with_capacity(specs.len()),
        singletons: Vec::new(),
    };
    for s in specs {
        node.children.push(build_node(
            data,
            params,
            dist,
            s.p,
            s.parent_dist,
            s.elems,
            s.owns_routing,
        ));
    }
    finish_aggregates(data, &mut node);
    node
}

/// Expansion stops once this many build tasks exist (fixed, never derived
/// from the thread count, so the task list and the order the per-task
/// distance tallies fold back in are functions of the data only).
const PAR_TASK_TARGET: usize = 64;
/// Specs smaller than this are not worth splitting during expansion.
const PAR_MIN_SPLIT: usize = 512;

/// Partially-built tree used by the parallel construction: expanded
/// interior nodes hold slots; unexpanded subtrees are either inline specs
/// (`Todo`) or handles into the parallel task list (`Task`).
enum Slot {
    Todo(ChildSpec),
    Task(usize),
    Open {
        routing: u32,
        parent_dist: f64,
        radius: f64,
        children: Vec<Slot>,
    },
}

fn count_todo(slot: &Slot) -> usize {
    match slot {
        Slot::Todo(_) => 1,
        Slot::Task(_) => 0,
        Slot::Open { children, .. } => children.iter().map(count_todo).sum(),
    }
}

/// Largest element count among still-splittable `Todo` specs.
fn max_splittable(slot: &Slot, params: &CoverTreeParams) -> Option<usize> {
    match slot {
        Slot::Todo(spec) => {
            (spec.splits(params) && spec.elems.len() >= PAR_MIN_SPLIT)
                .then_some(spec.elems.len())
        }
        Slot::Task(_) => None,
        Slot::Open { children, .. } => {
            children.iter().filter_map(|c| max_splittable(c, params)).max()
        }
    }
}

/// Expand (pre-order) the first splittable `Todo` with exactly `len`
/// elements into an `Open` node of child specs. Returns whether one was
/// expanded.
fn expand_one(
    slot: &mut Slot,
    len: usize,
    data: &Matrix,
    params: &CoverTreeParams,
    dist: &mut DistCounter,
) -> bool {
    match slot {
        Slot::Todo(spec) => {
            if !(spec.splits(params)
                && spec.elems.len() >= PAR_MIN_SPLIT
                && spec.elems.len() == len)
            {
                return false;
            }
            let ChildSpec { p, parent_dist, radius, elems, owns_routing } =
                match std::mem::replace(slot, Slot::Task(usize::MAX)) {
                    Slot::Todo(spec) => spec,
                    _ => unreachable!(),
                };
            let specs =
                partition_children(data, params, dist, p, radius, elems, owns_routing);
            *slot = Slot::Open {
                routing: p,
                parent_dist,
                radius,
                children: specs.into_iter().map(Slot::Todo).collect(),
            };
            true
        }
        Slot::Task(_) => false,
        Slot::Open { children, .. } => {
            for c in children.iter_mut() {
                if expand_one(c, len, data, params, dist) {
                    return true;
                }
            }
            false
        }
    }
}

/// Replace every `Todo` (pre-order) with a `Task` handle, collecting the
/// specs in handle order.
fn collect_tasks(slot: &mut Slot, out: &mut Vec<ChildSpec>) {
    match slot {
        Slot::Todo(_) => {
            let spec = match std::mem::replace(slot, Slot::Task(out.len())) {
                Slot::Todo(spec) => spec,
                _ => unreachable!(),
            };
            out.push(spec);
        }
        Slot::Task(_) => {}
        Slot::Open { children, .. } => {
            for c in children.iter_mut() {
                collect_tasks(c, out);
            }
        }
    }
}

/// Fold the slot tree back into real nodes, consuming the built task
/// results and recomputing the expanded interiors' aggregates bottom-up
/// (the same child-order summation the sequential build performs).
fn resolve_slots(slot: Slot, built: &mut [Option<Node>], data: &Matrix) -> Node {
    match slot {
        Slot::Task(i) => built[i].take().expect("task node consumed twice"),
        Slot::Open { routing, parent_dist, radius, children } => {
            let mut node = Node {
                routing,
                parent_dist,
                radius,
                sum: vec![0.0; data.cols()],
                weight: 0,
                children: children
                    .into_iter()
                    .map(|c| resolve_slots(c, built, data))
                    .collect(),
                singletons: Vec::new(),
            };
            finish_aggregates(data, &mut node);
            node
        }
        Slot::Todo(_) => unreachable!("todo specs collected before resolve"),
    }
}

/// Parallel construction driver: sequential expansion of the heaviest
/// specs (charging partition distances to the caller's counter in a fixed
/// order), concurrent subtree builds with private counters, then a
/// deterministic reassembly. Byte-identical to [`build_node`] on the same
/// input for any thread count.
fn build_root_parallel(
    data: &Matrix,
    params: &CoverTreeParams,
    dist: &mut DistCounter,
    root_pt: u32,
    elems: Vec<(u32, f64)>,
    par: &Parallelism,
) -> Node {
    let radius = elems.iter().fold(0.0f64, |m, &(_, dd)| m.max(dd));
    let mut root = Slot::Todo(ChildSpec {
        p: root_pt,
        parent_dist: 0.0,
        radius,
        elems,
        owns_routing: true,
    });
    while count_todo(&root) < PAR_TASK_TARGET {
        let Some(len) = max_splittable(&root, params) else { break };
        let expanded = expand_one(&mut root, len, data, params, dist);
        debug_assert!(expanded);
        if !expanded {
            break;
        }
    }
    let mut specs = Vec::new();
    collect_tasks(&mut root, &mut specs);
    let results = par.run_tasks(specs, |spec| {
        let mut dc = DistCounter::new();
        let node = build_node(
            data,
            params,
            &mut dc,
            spec.p,
            spec.parent_dist,
            spec.elems,
            spec.owns_routing,
        );
        (node, dc.count())
    });
    let mut built: Vec<Option<Node>> = Vec::with_capacity(results.len());
    for (node, count) in results {
        dist.add_bulk(count);
        built.push(Some(node));
    }
    resolve_slots(root, &mut built, data)
}

/// Bottom-up aggregation of `S_x` and `w_x` (paper §2.3).
fn finish_aggregates(data: &Matrix, node: &mut Node) {
    let d = data.cols();
    let mut sum = vec![0.0; d];
    let mut weight = 0u32;
    for ch in &node.children {
        for j in 0..d {
            sum[j] += ch.sum[j];
        }
        weight += ch.weight;
    }
    for &(idx, _) in &node.singletons {
        let row = data.row(idx as usize);
        for j in 0..d {
            sum[j] += row[j];
        }
        weight += 1;
    }
    node.sum = sum;
    node.weight = weight;
}

impl Node {
    /// (internal node count incl. self, total singleton entries).
    pub fn count_entries(&self) -> (usize, usize) {
        let mut nodes = 1;
        let mut singles = self.singletons.len();
        for ch in &self.children {
            let (n, s) = ch.count_entries();
            nodes += n;
            singles += s;
        }
        (nodes, singles)
    }

    /// Depth of the subtree (1 for a leaf).
    pub fn depth(&self) -> usize {
        1 + self.children.iter().map(|c| c.depth()).max().unwrap_or(0)
    }

    /// Visit every point index in the subtree.
    pub fn for_each_point(&self, f: &mut impl FnMut(u32)) {
        for &(idx, _) in &self.singletons {
            f(idx);
        }
        for ch in &self.children {
            ch.for_each_point(f);
        }
    }

    /// Centroid of the subtree (S_x / w_x).
    pub fn centroid(&self) -> Vec<f64> {
        let w = self.weight.max(1) as f64;
        self.sum.iter().map(|&s| s / w).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::dist as raw_dist;
    use crate::data::synth;

    fn check_invariants(data: &Matrix, node: &Node) {
        // 1. Radius invariant: every point in the subtree is within
        //    `radius` of the routing object (Eq. 6 soundness).
        let p = data.row(node.routing as usize);
        let mut count = 0u32;
        let mut sum = vec![0.0; data.cols()];
        node.for_each_point(&mut |idx| {
            let dd = raw_dist(p, data.row(idx as usize));
            assert!(
                dd <= node.radius + 1e-9,
                "point {idx} at {dd} > radius {}",
                node.radius
            );
            count += 1;
            for (j, v) in data.row(idx as usize).iter().enumerate() {
                sum[j] += v;
            }
        });
        // 2. Aggregates match.
        assert_eq!(count, node.weight);
        for j in 0..data.cols() {
            assert!((sum[j] - node.sum[j]).abs() < 1e-6 * (1.0 + sum[j].abs()));
        }
        // 3. Parent distances stored on children are true distances, and
        //    the self-child (index 0) shares the routing object.
        if let Some(first) = node.children.first() {
            assert_eq!(first.routing, node.routing);
            assert_eq!(first.parent_dist, 0.0);
        }
        for ch in &node.children {
            let dd = raw_dist(p, data.row(ch.routing as usize));
            assert!((dd - ch.parent_dist).abs() < 1e-9);
            // 4. Child radii shrink (cover invariant with scale factor).
            assert!(ch.radius <= node.radius + 1e-9);
            check_invariants(data, ch);
        }
        // 5. Singleton parent distances are true distances.
        for &(idx, pd) in &node.singletons {
            let dd = raw_dist(p, data.row(idx as usize));
            assert!((dd - pd).abs() < 1e-9);
        }
    }

    #[test]
    fn builds_and_obeys_invariants_blobs() {
        let data = synth::gaussian_blobs(500, 4, 5, 0.5, 1);
        let tree = CoverTree::build(
            &data,
            CoverTreeParams { scale_factor: 1.2, min_node_size: 10 },
        );
        assert_eq!(tree.len(), 500);
        assert_eq!(tree.singleton_count, 500);
        check_invariants(&data, &tree.root);
    }

    #[test]
    fn each_point_exactly_once() {
        let data = synth::istanbul(0.002, 3);
        let tree = CoverTree::build(
            &data,
            CoverTreeParams { scale_factor: 1.3, min_node_size: 25 },
        );
        let mut seen = vec![0u32; data.rows()];
        tree.root.for_each_point(&mut |i| seen[i as usize] += 1);
        assert!(seen.iter().all(|&c| c == 1));
    }

    #[test]
    fn duplicates_collapse_to_zero_radius_leaf() {
        // 200 copies of the same point + 10 others.
        let mut rows: Vec<Vec<f64>> = vec![vec![1.0, 2.0]; 200];
        for i in 0..10 {
            rows.push(vec![i as f64 * 10.0, -5.0]);
        }
        let refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
        let data = Matrix::from_rows(&refs);
        let tree = CoverTree::build(
            &data,
            CoverTreeParams { scale_factor: 1.2, min_node_size: 5 },
        );
        check_invariants(&data, &tree.root);
        // Find the duplicate leaf: some node must hold >= 200 points with
        // radius 0 (the paper's near-duplicate benefit).
        let mut found = false;
        fn visit(n: &Node, found: &mut bool) {
            if n.radius == 0.0 && n.weight >= 200 {
                *found = true;
            }
            for c in &n.children {
                visit(c, found);
            }
        }
        visit(&tree.root, &mut found);
        assert!(found, "expected a radius-0 node holding the duplicates");
    }

    #[test]
    fn min_node_size_respected() {
        let data = synth::gaussian_blobs(1000, 3, 4, 1.0, 2);
        let tree = CoverTree::build(
            &data,
            CoverTreeParams { scale_factor: 1.2, min_node_size: 100 },
        );
        // No internal node should have split a set smaller than min size:
        // children with < min points must be leaves.
        fn visit(n: &Node) {
            if (n.weight as usize) < 100 {
                assert!(
                    n.children.is_empty(),
                    "node with {} points was split",
                    n.weight
                );
            }
            for c in &n.children {
                visit(c);
            }
        }
        visit(&tree.root);
    }

    #[test]
    fn build_counts_distances() {
        let data = synth::gaussian_blobs(300, 3, 3, 0.5, 4);
        let tree = CoverTree::build(&data, CoverTreeParams::default());
        assert!(tree.build_distances >= 299, "at least root scan");
    }

    #[test]
    fn scale_factor_controls_depth() {
        let data = synth::gaussian_blobs(2000, 3, 5, 1.0, 5);
        let deep = CoverTree::build(
            &data,
            CoverTreeParams { scale_factor: 1.1, min_node_size: 10 },
        );
        let shallow = CoverTree::build(
            &data,
            CoverTreeParams { scale_factor: 2.0, min_node_size: 10 },
        );
        assert!(shallow.root.depth() <= deep.root.depth());
    }

    #[test]
    fn centroid_matches_mean() {
        let data = Matrix::from_rows(&[&[0.0, 0.0], &[2.0, 0.0], &[1.0, 3.0]]);
        let tree = CoverTree::build(&data, CoverTreeParams::default());
        let c = tree.root.centroid();
        assert!((c[0] - 1.0).abs() < 1e-12 && (c[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "scale factor")]
    fn rejects_bad_scale() {
        let data = Matrix::from_rows(&[&[0.0]]);
        CoverTree::build(&data, CoverTreeParams { scale_factor: 0.9, min_node_size: 1 });
    }
}
