"""Build-time compile path: L2 JAX model + L1 Pallas kernels + AOT export.

Nothing in this package is imported at request time; the Rust binary only
consumes the HLO-text artifacts that ``python -m compile.aot`` writes.
"""
