"""L2: the JAX compute graph served to the Rust coordinator.

``assign_step`` is the dense assignment + centroid-partial step of k-means
(paper Eqs. 1-2) over one padded chunk of points, calling the L1 Pallas
kernel so that both lower into a single HLO module.  ``aot.py`` lowers this
function once per (d, k) lattice shape into ``artifacts/*.hlo.txt``; the
Rust runtime (rust/src/runtime/) loads and executes those artifacts on the
PJRT CPU client.  Python never runs at request time.

Chunk protocol (mirrored by rust/src/runtime/executor.rs):
  * points are processed in chunks of ``CHUNK`` rows; the final partial
    chunk is zero-padded with weight 0,
  * d is zero-padded up to the lattice d (distance-preserving),
  * k is padded up to the lattice k with ``PAD_CENTER_VALUE`` sentinel
    centers (never an argmin winner for real data).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import assign as assign_kernel
from .kernels import ref as assign_ref_mod

CHUNK = 1024
BLOCK_C = assign_kernel.DEFAULT_BLOCK_C


def assign_step(x: jnp.ndarray, w: jnp.ndarray, centers: jnp.ndarray):
    """One chunk of the dense assign step.  Returns a 5-tuple.

    (labels i32[c], d1 f32[c], d2 f32[c], sums f32[k,d], counts f32[k]).
    """
    return tuple(assign_kernel.assign_pallas(x, w, centers, block_c=BLOCK_C))


def assign_step_ref(x: jnp.ndarray, w: jnp.ndarray, centers: jnp.ndarray):
    """Pure-jnp twin of :func:`assign_step` (weighted), for L2 testing."""
    labels, d1, d2, _sums, _counts = assign_ref_mod.assign_ref(x, centers)
    k = centers.shape[0]
    onehot = (jnp.arange(k)[None, :] == labels[:, None]).astype(x.dtype)
    onehot = onehot * w[:, None]
    sums = onehot.T @ x
    counts = jnp.sum(onehot, axis=0)
    return labels, d1, d2, sums, counts


def lower_assign(d: int, k: int, chunk: int = CHUNK):
    """Lower ``assign_step`` for a concrete (chunk, d, k) shape."""
    x = jax.ShapeDtypeStruct((chunk, d), jnp.float32)
    w = jax.ShapeDtypeStruct((chunk,), jnp.float32)
    c = jax.ShapeDtypeStruct((k, d), jnp.float32)
    return jax.jit(assign_step).lower(x, w, c)
