"""L1 Pallas kernel: weighted top-2 nearest-center assignment.

The dense hot-spot of every k-means baseline in the paper is the assignment
step (Eq. 1): for each point, the distance to every candidate center.  The
paper's accelerated algorithms exist to *avoid* this work; the Standard
baseline (and the first iteration of every stored-bounds algorithm) must pay
it in full, so it is the kernel we AOT-compile and serve from Rust.

Kernel contract (one ``pallas_call``):

    inputs : x (c, d) f32, w (c,) f32 weights, centers (k, d) f32
    outputs: labels (c,) i32, d1 (c,) f32, d2 (c,) f32,
             sums (k, d) f32, counts (k,) f32

``w`` is 1.0 for live rows and 0.0 for padding rows (the Rust runtime pads
chunks up to the compiled lattice shape); it also directly supports
*weighted* points, which is how cover-tree node aggregates (S_x, w_x) are
clustered when running Lloyd over tree leaves.

TPU mapping: the points chunk is tiled
into ``block_c``-row blocks streamed HBM->VMEM by the BlockSpec grid; the
full center matrix stays VMEM-resident across the grid (k <= 1024, d <= 128
=> <= 512 KiB f32).  The distance expansion ||x||^2 + ||c||^2 - 2 x.C^T puts
the dominant FLOPs in a (block_c, d) x (d, k) matmul that targets the MXU;
the top-2 reduction and the one-hot partial-sum matmul reuse the same
VMEM-resident tiles.  ``interpret=True`` everywhere: the CPU PJRT plugin
cannot execute Mosaic custom-calls, so the kernel is lowered through the
Pallas interpreter into plain HLO (same numerics, same schedule structure).

The pure-jnp oracle lives in :mod:`compile.kernels.ref`; pytest + hypothesis
assert allclose between the two over a sweep of shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Center coordinates used to pad k up to a compiled lattice size.  Large
# enough that a sentinel center can never be the (first or second) argmin
# for real data, small enough that the squared-distance expansion stays
# finite in f32 (1e15^2 * d <= ~1.3e32 << f32 max 3.4e38 for d <= 128).
PAD_CENTER_VALUE = 1.0e15

DEFAULT_BLOCK_C = 256


def _assign_kernel(x_ref, w_ref, c_ref, labels_ref, d1_ref, d2_ref,
                   sums_ref, counts_ref):
    """One grid step: assign a block of points against all centers."""
    pid = pl.program_id(0)
    x = x_ref[...]                       # (bc, d)
    w = w_ref[...]                       # (bc,)
    c = c_ref[...]                       # (k, d)
    k = c.shape[0]

    # ||x - c||^2 = ||x||^2 + ||c||^2 - 2 <x, c>; the matmul is the MXU op.
    x2 = jnp.sum(x * x, axis=1, keepdims=True)          # (bc, 1)
    c2 = jnp.sum(c * c, axis=1)[None, :]                # (1, k)
    dots = jnp.dot(x, c.T, preferred_element_type=jnp.float32)
    sq = jnp.maximum(x2 + c2 - 2.0 * dots, 0.0)         # (bc, k)

    iota_k = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)
    labels = jnp.argmin(sq, axis=1).astype(jnp.int32)   # ties: lowest index
    d1sq = jnp.min(sq, axis=1)
    masked = jnp.where(iota_k == labels[:, None], jnp.inf, sq)
    d2sq = jnp.min(masked, axis=1)

    labels_ref[...] = labels
    d1_ref[...] = jnp.sqrt(d1sq)
    d2_ref[...] = jnp.sqrt(d2sq)

    # Weighted one-hot partial sums for the centroid update (Eq. 2).  The
    # accumulator blocks are shared by every grid step (constant index_map);
    # the TPU grid is sequential, so read-modify-write accumulation is safe
    # (and the interpreter preserves that ordering).
    onehot = (iota_k == labels[:, None]).astype(x.dtype) * w[:, None]
    sums_update = jnp.dot(onehot.T, x, preferred_element_type=jnp.float32)
    counts_update = jnp.sum(onehot, axis=0)

    @pl.when(pid == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    sums_ref[...] += sums_update
    counts_ref[...] += counts_update


@functools.partial(jax.jit, static_argnames=("block_c",))
def assign_pallas(x: jnp.ndarray, w: jnp.ndarray, centers: jnp.ndarray,
                  block_c: int = DEFAULT_BLOCK_C):
    """Weighted top-2 assignment over a padded chunk.

    ``x.shape[0]`` must be a multiple of ``block_c`` (the AOT lattice shapes
    are); use :func:`compile.kernels.ref.assign_ref` for arbitrary shapes.
    """
    c_points, d = x.shape
    k = centers.shape[0]
    if c_points % block_c != 0:
        raise ValueError(f"chunk {c_points} not a multiple of block_c {block_c}")
    grid = (c_points // block_c,)
    return pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_c, d), lambda i: (i, 0)),   # stream points
            pl.BlockSpec((block_c,), lambda i: (i,)),       # stream weights
            pl.BlockSpec((k, d), lambda i: (0, 0)),         # centers resident
        ],
        out_specs=[
            pl.BlockSpec((block_c,), lambda i: (i,)),
            pl.BlockSpec((block_c,), lambda i: (i,)),
            pl.BlockSpec((block_c,), lambda i: (i,)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),         # accumulators:
            pl.BlockSpec((k,), lambda i: (0,)),             # same block each step
        ],
        out_shape=[
            jax.ShapeDtypeStruct((c_points,), jnp.int32),
            jax.ShapeDtypeStruct((c_points,), jnp.float32),
            jax.ShapeDtypeStruct((c_points,), jnp.float32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, w, centers)


def vmem_estimate_bytes(block_c: int, d: int, k: int) -> int:
    """Static VMEM footprint estimate for one grid step (f32).

    Recorded in the artifact manifest (``covermeans info``): inputs
    (x, w, centers), the (block_c, k) distance tile, and the accumulators
    all co-resident.
    """
    f = 4
    return f * (
        block_c * d        # x block
        + block_c          # w block
        + k * d            # centers
        + 2 * block_c * k  # sq + masked tiles
        + k * d + k        # accumulators
        + 3 * block_c      # labels/d1/d2
    )


def mxu_fraction(block_c: int, d: int, k: int) -> float:
    """Fraction of kernel FLOPs that are matmul (MXU-eligible) FLOPs."""
    matmul = 2.0 * block_c * d * k * 2          # x.C^T and onehot^T.x
    elementwise = (
        block_c * d * 2 + k * d * 2             # x2, c2
        + block_c * k * 3                       # sq combine + clamp
        + block_c * k * 2                       # two min/argmin passes
        + block_c * k                           # onehot scale
        + block_c * 3                           # sqrt etc.
    )
    return matmul / (matmul + elementwise)
