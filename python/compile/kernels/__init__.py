"""Pallas kernels (L1) and their pure-jnp oracles."""

from . import assign, ref  # noqa: F401
