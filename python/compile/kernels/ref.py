"""Pure-jnp correctness oracle for the assign-step kernel.

This is the semantic specification that both the Pallas kernel
(:mod:`compile.kernels.assign`) and the Rust native assignment path must
agree with: given a chunk of points ``x`` (c, d) and centers (k, d), compute

* ``labels``  (c,)   int32 — index of the nearest center (ties: lowest index),
* ``d1``      (c,)   f32   — distance to the nearest center,
* ``d2``      (c,)   f32   — distance to the second-nearest center,
* ``sums``    (k, d) f32   — per-cluster partial sums of assigned points,
* ``counts``  (k,)   f32   — per-cluster assigned-point counts.

Distances are Euclidean.  The top-2 outputs are exactly what the paper's
stored-bounds algorithms (Hamerly/Exponion/Shallot, and the Hybrid hand-off
of Eqs. 15-18) need as upper/lower bound seeds.
"""

from __future__ import annotations

import jax.numpy as jnp


def pairwise_sqdist(x: jnp.ndarray, centers: jnp.ndarray) -> jnp.ndarray:
    """Squared Euclidean distances, (c, k), via the expanded form.

    ||x - c||^2 = ||x||^2 + ||c||^2 - 2 <x, c>.  The matmul term is what
    maps onto the MXU on real hardware; the clamp guards the tiny negative
    values the expansion can produce in floating point.
    """
    x2 = jnp.sum(x * x, axis=1, keepdims=True)          # (c, 1)
    c2 = jnp.sum(centers * centers, axis=1)[None, :]    # (1, k)
    sq = x2 + c2 - 2.0 * (x @ centers.T)
    return jnp.maximum(sq, 0.0)


def assign_ref(x: jnp.ndarray, centers: jnp.ndarray):
    """Reference assign step: top-2 nearest centers + centroid partials."""
    k = centers.shape[0]
    sq = pairwise_sqdist(x, centers)                    # (c, k)
    labels = jnp.argmin(sq, axis=1).astype(jnp.int32)
    d1sq = jnp.min(sq, axis=1)
    # Mask out the winner to find the runner-up.  With k == 1 there is no
    # second center; d2 is +inf then (matches the Rust side).
    masked = jnp.where(jnp.arange(k)[None, :] == labels[:, None], jnp.inf, sq)
    d2sq = jnp.min(masked, axis=1)
    onehot = (jnp.arange(k)[None, :] == labels[:, None]).astype(x.dtype)
    sums = onehot.T @ x                                  # (k, d)
    counts = jnp.sum(onehot, axis=0)                     # (k,)
    return labels, jnp.sqrt(d1sq), jnp.sqrt(d2sq), sums, counts
