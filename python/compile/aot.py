"""AOT export: lower the L2 assign-step to HLO *text* artifacts.

Run as ``python -m compile.aot --out-dir ../artifacts`` (what ``make
artifacts`` does).  For every (d, k) lattice shape this writes
``assign_d{D}_k{K}.hlo.txt`` plus a ``manifest.tsv`` that the Rust runtime
reads to pick the smallest compiled shape covering a request.

HLO **text** is the interchange format, not ``HloModuleProto.serialize()``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` 0.1.6 crate binds) rejects with
``proto.id() <= INT_MAX``.  The text parser on the Rust side reassigns ids,
so text round-trips cleanly.  See /opt/xla-example/README.md.
"""

from __future__ import annotations

import argparse
import os
import sys

from jax._src.lib import xla_client as xc

from . import model
from .kernels import assign as assign_kernel

# (d, k) lattice.  d covers the paper's datasets (2-d geo .. 74-d KDD04,
# padded to the next lattice point); k covers the paper's sweeps (k=10 ..
# 1000).  Chunk is fixed at model.CHUNK rows.
LATTICE_D = (2, 8, 16, 32, 64, 80, 128)
LATTICE_K = (16, 64, 128, 256, 512, 1024)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def artifact_name(d: int, k: int, chunk: int = model.CHUNK) -> str:
    return f"assign_c{chunk}_d{d}_k{k}.hlo.txt"


def export_one(out_dir: str, d: int, k: int, chunk: int = model.CHUNK) -> str:
    lowered = model.lower_assign(d, k, chunk)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, artifact_name(d, k, chunk))
    with open(path, "w") as f:
        f.write(text)
    return path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None,
                    help="legacy single-artifact path (writes the d=8,k=16 "
                         "quickstart shape there in addition to the lattice)")
    ap.add_argument("--lattice-d", default=",".join(map(str, LATTICE_D)))
    ap.add_argument("--lattice-k", default=",".join(map(str, LATTICE_K)))
    ap.add_argument("--chunk", type=int, default=model.CHUNK)
    args = ap.parse_args(argv)

    ds = [int(x) for x in args.lattice_d.split(",") if x]
    ks = [int(x) for x in args.lattice_k.split(",") if x]
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_rows = []
    for d in ds:
        for k in ks:
            path = export_one(args.out_dir, d, k, args.chunk)
            vmem = assign_kernel.vmem_estimate_bytes(model.BLOCK_C, d, k)
            mxu = assign_kernel.mxu_fraction(model.BLOCK_C, d, k)
            manifest_rows.append(
                (args.chunk, d, k, os.path.basename(path), vmem, f"{mxu:.4f}")
            )
            print(f"wrote {path} (VMEM est {vmem/1024:.0f} KiB, "
                  f"MXU FLOP fraction {mxu:.3f})", file=sys.stderr)

    manifest = os.path.join(args.out_dir, "manifest.tsv")
    with open(manifest, "w") as f:
        f.write("# chunk\td\tk\tfile\tvmem_bytes\tmxu_fraction\n")
        for row in manifest_rows:
            f.write("\t".join(str(x) for x in row) + "\n")
    print(f"wrote {manifest} ({len(manifest_rows)} artifacts)", file=sys.stderr)

    if args.out:
        # Back-compat with the original Makefile target layout.
        lowered = model.lower_assign(8, 16, args.chunk)
        with open(args.out, "w") as f:
            f.write(to_hlo_text(lowered))
        print(f"wrote {args.out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
