"""L2 tests: assign_step shapes, padding protocol, weighted semantics."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import assign as assign_kernel


def test_assign_step_shapes():
    x = jnp.zeros((model.CHUNK, 8), jnp.float32)
    w = jnp.ones((model.CHUNK,), jnp.float32)
    c = jnp.zeros((16, 8), jnp.float32)
    labels, d1, d2, sums, counts = model.assign_step(x, w, c)
    assert labels.shape == (model.CHUNK,) and labels.dtype == jnp.int32
    assert d1.shape == d2.shape == (model.CHUNK,)
    assert sums.shape == (16, 8) and counts.shape == (16,)


def test_assign_step_matches_ref_twin():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(model.CHUNK, 16)).astype(np.float32)
    w = (rng.random(model.CHUNK) < 0.9).astype(np.float32)
    c = rng.normal(size=(64, 16)).astype(np.float32)
    out_k = model.assign_step(jnp.array(x), jnp.array(w), jnp.array(c))
    out_r = model.assign_step_ref(jnp.array(x), jnp.array(w), jnp.array(c))
    names = ["labels", "d1", "d2", "sums", "counts"]
    for a, b, n in zip(out_k, out_r, names):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-3, err_msg=n)


def test_d_zero_padding_preserves_distances():
    """The runtime pads d with zero columns; distances must be unchanged."""
    rng = np.random.default_rng(1)
    d_real, d_pad = 5, 8
    x = rng.normal(size=(model.CHUNK, d_real)).astype(np.float32)
    c = rng.normal(size=(16, d_real)).astype(np.float32)
    w = np.ones(model.CHUNK, np.float32)
    xp = np.zeros((model.CHUNK, d_pad), np.float32); xp[:, :d_real] = x
    cp = np.zeros((16, d_pad), np.float32); cp[:, :d_real] = c
    out = model.assign_step(jnp.array(x), jnp.array(w), jnp.array(c))
    outp = model.assign_step(jnp.array(xp), jnp.array(w), jnp.array(cp))
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(outp[0]))
    # f32 reduction order differs between d=5 and padded d=8 lanes.
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(outp[1]),
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out[3]),
                               np.asarray(outp[3])[:, :d_real], rtol=1e-5,
                               atol=1e-4)


def test_full_padding_protocol_roundtrip():
    """Replicate exactly what rust runtime/executor.rs does for an odd
    request (n=700, d=5, k=10) against lattice (chunk=1024, d=8, k=16)."""
    rng = np.random.default_rng(2)
    n, d, k = 700, 5, 10
    dl, kl = 8, 16
    x = rng.normal(size=(n, d)).astype(np.float32)
    c = rng.normal(size=(k, d)).astype(np.float32)

    xp = np.zeros((model.CHUNK, dl), np.float32); xp[:n, :d] = x
    wp = np.zeros(model.CHUNK, np.float32); wp[:n] = 1.0
    cp = np.full((kl, dl), assign_kernel.PAD_CENTER_VALUE, np.float32)
    cp[:k, :] = 0.0
    cp[:k, :d] = c

    labels, d1, d2, sums, counts = (
        np.asarray(o) for o in model.assign_step(
            jnp.array(xp), jnp.array(wp), jnp.array(cp)))

    # Oracle on the unpadded problem.
    from compile.kernels import ref
    rl, rd1, rd2, rsums, rcounts = (np.asarray(o) for o in
                                    ref.assign_ref(jnp.array(x), jnp.array(c)))
    np.testing.assert_array_equal(labels[:n], rl)
    np.testing.assert_allclose(d1[:n], rd1, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(d2[:n], rd2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(sums[:k, :d], rsums, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(counts[:k], rcounts, rtol=1e-6)
    assert counts[k:].sum() == 0.0
    assert np.abs(sums[k:, :]).sum() == 0.0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       frac=st.floats(0.0, 1.0))
def test_weight_linearity(seed, frac):
    """sums/counts are linear in w: splitting weights across two calls and
    adding equals one call with the summed weights."""
    rng = np.random.default_rng(seed)
    n, d, k = 256, 4, 8
    # pad n to CHUNK
    x = np.zeros((model.CHUNK, d), np.float32)
    x[:n] = rng.normal(size=(n, d))
    w = np.zeros(model.CHUNK, np.float32)
    w[:n] = rng.random(n)
    c = rng.normal(size=(k, d)).astype(np.float32)
    w1 = w * frac
    w2 = w - w1
    out = model.assign_step(jnp.array(x), jnp.array(w), jnp.array(c))
    o1 = model.assign_step(jnp.array(x), jnp.array(w1), jnp.array(c))
    o2 = model.assign_step(jnp.array(x), jnp.array(w2), jnp.array(c))
    np.testing.assert_allclose(np.asarray(o1[3]) + np.asarray(o2[3]),
                               np.asarray(out[3]), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(o1[4]) + np.asarray(o2[4]),
                               np.asarray(out[4]), rtol=1e-5, atol=1e-5)
