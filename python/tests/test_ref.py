"""Oracle self-checks: the pure-jnp reference must itself agree with a
straightforward numpy brute force (if the oracle is wrong, every kernel
test is vacuous)."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def brute(x, c):
    d = np.linalg.norm(x[:, None, :] - c[None, :, :], axis=2)
    labels = d.argmin(axis=1)
    d1 = d.min(axis=1)
    d_masked = d.copy()
    d_masked[np.arange(len(x)), labels] = np.inf
    d2 = d_masked.min(axis=1)
    return labels, d1, d2


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 80),
    d=st.integers(1, 10),
    k=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_ref_matches_numpy_brute_force(n, d, k, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    c = rng.normal(size=(k, d)).astype(np.float32)
    labels, d1, d2, sums, counts = (
        np.asarray(o) for o in ref.assign_ref(jnp.array(x), jnp.array(c)))
    bl, bd1, bd2 = brute(x, c)
    np.testing.assert_array_equal(labels, bl)
    np.testing.assert_allclose(d1, bd1, rtol=1e-3, atol=1e-3)
    if k > 1:
        np.testing.assert_allclose(d2, bd2, rtol=1e-3, atol=1e-3)
    # partials
    np.testing.assert_allclose(counts.sum(), n, rtol=1e-6)
    np.testing.assert_allclose(sums.sum(axis=0), x.sum(axis=0),
                               rtol=1e-3, atol=1e-3)


def test_pairwise_sqdist_nonnegative_and_zero_diag():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(20, 6)).astype(np.float32) * 100
    sq = np.asarray(ref.pairwise_sqdist(jnp.array(x), jnp.array(x)))
    assert (sq >= 0).all()
    # The expanded form's absolute error scales with f32 eps * ||x||^2.
    atol = 1e-6 * float((x * x).sum(axis=1).max())
    assert np.allclose(np.diag(sq), 0.0, atol=atol)


def test_tie_breaks_to_lowest_index():
    x = np.zeros((4, 2), np.float32)
    c = np.array([[1.0, 0.0], [-1.0, 0.0], [1.0, 0.0]], np.float32)
    labels = np.asarray(ref.assign_ref(jnp.array(x), jnp.array(c))[0])
    assert (labels == 0).all()
