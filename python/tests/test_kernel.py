"""L1 correctness: Pallas assign kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes (chunk multiples of the block, d, k) and data
regimes (normal, duplicates, large magnitudes); every output of the kernel
must match ``ref.assign_ref`` to f32 tolerance, and the integer outputs
must match exactly.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import assign, ref

BLOCK = 64  # small block for test speed; production uses 256


def run_both(x, w, c, block=BLOCK):
    out_k = assign.assign_pallas(jnp.array(x), jnp.array(w), jnp.array(c),
                                 block_c=block)
    labels, d1, d2, sums, counts = (np.asarray(o) for o in out_k)
    rl, rd1, rd2, rsums, rcounts = (np.asarray(o)
                                    for o in ref.assign_ref(jnp.array(x),
                                                            jnp.array(c)))
    return (labels, d1, d2, sums, counts), (rl, rd1, rd2, rsums, rcounts)


def check_match(x, w, c, block=BLOCK):
    (labels, d1, d2, sums, counts), (rl, rd1, rd2, rsums, rcounts) = \
        run_both(x, w, c, block)
    np.testing.assert_array_equal(labels, rl)
    np.testing.assert_allclose(d1, rd1, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(d2, rd2, rtol=1e-4, atol=1e-4)
    # weighted partials: recompute the weighted oracle
    k = c.shape[0]
    onehot = (np.arange(k)[None, :] == rl[:, None]).astype(np.float32)
    onehot *= w[:, None]
    np.testing.assert_allclose(sums, onehot.T @ x, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(counts, onehot.sum(axis=0), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    nblocks=st.integers(1, 3),
    d=st.integers(1, 24),
    k=st.integers(2, 17),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_random(nblocks, d, k, seed):
    rng = np.random.default_rng(seed)
    n = nblocks * BLOCK
    x = rng.normal(size=(n, d)).astype(np.float32)
    w = np.ones(n, np.float32)
    c = rng.normal(size=(k, d)).astype(np.float32)
    check_match(x, w, c)


@settings(max_examples=15, deadline=None)
@given(
    scale=st.sampled_from([1e-3, 1.0, 1e3, 1e5]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_magnitude_regimes(scale, seed):
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(BLOCK, 8)) * scale).astype(np.float32)
    w = np.ones(BLOCK, np.float32)
    c = (rng.normal(size=(7, 8)) * scale).astype(np.float32)
    check_match(x, w, c)


def test_duplicate_points_and_centers():
    # Traffic-like regime: many exact duplicates; ties must break to the
    # lowest center index in both implementations.
    rng = np.random.default_rng(7)
    base = rng.normal(size=(4, 3)).astype(np.float32)
    x = np.repeat(base, BLOCK // 4, axis=0)
    w = np.ones(BLOCK, np.float32)
    c = np.vstack([base[0], base[0], base[2]]).astype(np.float32)  # dup centers
    (labels, d1, _, _, counts), _ = run_both(x, w, c)
    assert set(np.unique(labels)) <= {0, 2}          # index 1 never wins ties
    np.testing.assert_allclose(d1[: BLOCK // 4], 0.0, atol=1e-6)
    assert counts.sum() == BLOCK


def test_zero_weight_rows_excluded_from_partials():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(BLOCK, 5)).astype(np.float32)
    w = np.zeros(BLOCK, np.float32)
    w[: BLOCK // 2] = 1.0
    c = rng.normal(size=(6, 5)).astype(np.float32)
    (labels, _, _, sums, counts), _ = run_both(x, w, c)
    assert counts.sum() == BLOCK // 2
    k = c.shape[0]
    onehot = (np.arange(k)[None, :] == labels[:, None]).astype(np.float32)
    onehot[BLOCK // 2:] = 0.0
    np.testing.assert_allclose(sums, onehot.T @ x, rtol=1e-4, atol=1e-4)


def test_sentinel_padded_centers_never_win():
    rng = np.random.default_rng(11)
    x = rng.normal(size=(BLOCK, 8)).astype(np.float32)
    w = np.ones(BLOCK, np.float32)
    c = rng.normal(size=(5, 8)).astype(np.float32)
    cpad = np.vstack(
        [c, np.full((11, 8), assign.PAD_CENTER_VALUE, np.float32)])
    (labels, d1, d2, _, counts), _ = run_both(x, w, c)
    (lp, d1p, d2p, _, cp), _ = run_both(x, w, cpad)
    np.testing.assert_array_equal(labels, lp)
    np.testing.assert_allclose(d1, d1p, rtol=1e-6)
    np.testing.assert_allclose(d2, d2p, rtol=1e-6)
    assert cp[5:].sum() == 0.0


def test_single_center_d2_is_inf():
    x = np.zeros((BLOCK, 2), np.float32)
    w = np.ones(BLOCK, np.float32)
    c = np.ones((1, 2), np.float32)
    out = assign.assign_pallas(jnp.array(x), jnp.array(w), jnp.array(c),
                               block_c=BLOCK)
    assert np.all(np.isinf(np.asarray(out[2])))
    np.testing.assert_allclose(np.asarray(out[1]), np.sqrt(2.0), rtol=1e-6)


def test_rejects_non_multiple_chunk():
    x = jnp.zeros((BLOCK + 1, 2), jnp.float32)
    w = jnp.zeros((BLOCK + 1,), jnp.float32)
    c = jnp.zeros((2, 2), jnp.float32)
    with pytest.raises(ValueError):
        assign.assign_pallas(x, w, c, block_c=BLOCK)


def test_pad_center_value_finite_sqdist():
    # The sentinel must not overflow the f32 expansion for the largest
    # lattice d; NaNs here would poison argmin.
    d = 128
    x = np.full((BLOCK, d), 100.0, np.float32)
    w = np.ones(BLOCK, np.float32)
    c = np.vstack([np.zeros((1, d), np.float32),
                   np.full((1, d), assign.PAD_CENTER_VALUE, np.float32)])
    out = assign.assign_pallas(jnp.array(x), jnp.array(w), jnp.array(c),
                               block_c=BLOCK)
    assert not np.any(np.isnan(np.asarray(out[1])))
    np.testing.assert_array_equal(np.asarray(out[0]), 0)
