"""AOT export tests: HLO text well-formedness, manifest, determinism."""

import os

from compile import aot, model


def test_to_hlo_text_wellformed():
    txt = aot.to_hlo_text(model.lower_assign(2, 16))
    assert txt.startswith("HloModule")
    assert "ENTRY" in txt
    # 5-tuple root: labels, d1, d2, sums, counts
    assert "(s32[1024]" in txt.replace(" ", "")[:20000] or "s32[1024]" in txt


def test_export_deterministic():
    a = aot.to_hlo_text(model.lower_assign(2, 16))
    b = aot.to_hlo_text(model.lower_assign(2, 16))
    assert a == b


def test_export_one_and_manifest(tmp_path):
    rc = aot.main(["--out-dir", str(tmp_path),
                   "--lattice-d", "2", "--lattice-k", "16"])
    assert rc == 0
    files = os.listdir(tmp_path)
    assert aot.artifact_name(2, 16) in files
    assert "manifest.tsv" in files
    rows = [l for l in open(tmp_path / "manifest.tsv")
            if not l.startswith("#")]
    assert len(rows) == 1
    chunk, d, k, fname, vmem, mxu = rows[0].split("\t")
    assert (int(chunk), int(d), int(k)) == (model.CHUNK, 2, 16)
    assert fname == aot.artifact_name(2, 16)
    assert int(vmem) > 0 and 0.0 < float(mxu) < 1.0


def test_artifact_name_format():
    assert aot.artifact_name(64, 512) == "assign_c1024_d64_k512.hlo.txt"
